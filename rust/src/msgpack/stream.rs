//! Streaming MessagePack layer: a zero-copy pull-parser ([`Reader`]) over a
//! flat `&[u8]` and a direct-to-buffer emitter ([`Writer`]).
//!
//! The owned [`super::Value`] tree costs one `BTreeMap` plus a `String` per
//! field name on every decode — per-message overhead the paper's whole
//! argument says the runtime cannot afford. The hot-path protocol messages
//! (task assignment, `task-finished`, steal request/answer, data placement)
//! instead decode straight from the frame bytes with borrowed `&str` /
//! `&[u8]` views and encode straight into a caller-reused `Vec<u8>`, with
//! zero heap allocations on either side.
//!
//! The emitters here are the *only* place format selection happens: the
//! [`Writer`] always picks the smallest representation (canonical form), and
//! [`super::encode`] delegates to the same primitives, so the streaming
//! codec and the `Value`-tree codec are byte-identical by construction —
//! property-tested in `protocol::codec`.

use super::decode::DecodeError;

// ---------------------------------------------------------------------------
// Emit primitives (shared with the Value-tree encoder)
// ---------------------------------------------------------------------------

pub(crate) fn write_uint(out: &mut Vec<u8>, u: u64) {
    if u <= 0x7f {
        out.push(u as u8); // positive fixint
    } else if u <= u8::MAX as u64 {
        out.push(0xcc);
        out.push(u as u8);
    } else if u <= u16::MAX as u64 {
        out.push(0xcd);
        out.extend_from_slice(&(u as u16).to_be_bytes());
    } else if u <= u32::MAX as u64 {
        out.push(0xce);
        out.extend_from_slice(&(u as u32).to_be_bytes());
    } else {
        out.push(0xcf);
        out.extend_from_slice(&u.to_be_bytes());
    }
}

pub(crate) fn write_int(out: &mut Vec<u8>, i: i64) {
    if i >= 0 {
        return write_uint(out, i as u64);
    }
    if i >= -32 {
        out.push(i as u8); // negative fixint 0xe0..0xff
    } else if i >= i8::MIN as i64 {
        out.push(0xd0);
        out.push(i as i8 as u8);
    } else if i >= i16::MIN as i64 {
        out.push(0xd1);
        out.extend_from_slice(&(i as i16).to_be_bytes());
    } else if i >= i32::MIN as i64 {
        out.push(0xd2);
        out.extend_from_slice(&(i as i32).to_be_bytes());
    } else {
        out.push(0xd3);
        out.extend_from_slice(&i.to_be_bytes());
    }
}

pub(crate) fn write_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    match b.len() {
        0..=31 => out.push(0xa0 | b.len() as u8),
        32..=255 => {
            out.push(0xd9);
            out.push(b.len() as u8);
        }
        256..=65535 => {
            out.push(0xda);
            out.extend_from_slice(&(b.len() as u16).to_be_bytes());
        }
        _ => {
            out.push(0xdb);
            out.extend_from_slice(&(b.len() as u32).to_be_bytes());
        }
    }
    out.extend_from_slice(b);
}

/// Emit only the bin *header* (format byte + length) for a payload of
/// `len` bytes — the payload itself is supplied by the caller, possibly
/// from a different buffer entirely. This is what lets the data plane
/// stream a stored `Arc<Vec<u8>>` onto the wire without copying it into
/// the encode buffer: header and trailing fields are encoded normally,
/// the payload bytes travel as their own write. Byte-compatible with
/// [`write_bin`] by construction (that function delegates here).
pub(crate) fn write_bin_header(out: &mut Vec<u8>, len: usize) {
    match len {
        0..=255 => {
            out.push(0xc4);
            out.push(len as u8);
        }
        256..=65535 => {
            out.push(0xc5);
            out.extend_from_slice(&(len as u16).to_be_bytes());
        }
        _ => {
            out.push(0xc6);
            out.extend_from_slice(&(len as u32).to_be_bytes());
        }
    }
}

pub(crate) fn write_bin(out: &mut Vec<u8>, b: &[u8]) {
    write_bin_header(out, b.len());
    out.extend_from_slice(b);
}

pub(crate) fn write_array_header(out: &mut Vec<u8>, n: usize) {
    match n {
        0..=15 => out.push(0x90 | n as u8),
        16..=65535 => {
            out.push(0xdc);
            out.extend_from_slice(&(n as u16).to_be_bytes());
        }
        _ => {
            out.push(0xdd);
            out.extend_from_slice(&(n as u32).to_be_bytes());
        }
    }
}

pub(crate) fn write_map_header(out: &mut Vec<u8>, n: usize) {
    match n {
        0..=15 => out.push(0x80 | n as u8),
        16..=65535 => {
            out.push(0xde);
            out.extend_from_slice(&(n as u16).to_be_bytes());
        }
        _ => {
            out.push(0xdf);
            out.extend_from_slice(&(n as u32).to_be_bytes());
        }
    }
}

/// Direct-to-buffer MessagePack emitter. Appends to a caller-owned `Vec` so
/// a connection can reuse one output buffer for every message it sends.
pub struct Writer<'b> {
    out: &'b mut Vec<u8>,
}

impl<'b> Writer<'b> {
    pub fn new(out: &'b mut Vec<u8>) -> Writer<'b> {
        Writer { out }
    }

    pub fn nil(&mut self) {
        self.out.push(0xc0);
    }

    pub fn boolean(&mut self, b: bool) {
        self.out.push(if b { 0xc3 } else { 0xc2 });
    }

    pub fn uint(&mut self, u: u64) {
        write_uint(self.out, u);
    }

    pub fn int(&mut self, i: i64) {
        write_int(self.out, i);
    }

    pub fn f64(&mut self, f: f64) {
        self.out.push(0xcb);
        self.out.extend_from_slice(&f.to_be_bytes());
    }

    pub fn str(&mut self, s: &str) {
        write_str(self.out, s);
    }

    pub fn bin(&mut self, b: &[u8]) {
        write_bin(self.out, b);
    }

    /// Emit a bin header for `len` payload bytes without the payload.
    /// The caller is responsible for supplying exactly `len` bytes next
    /// (typically via a separate zero-copy write of a stored buffer).
    pub fn bin_header(&mut self, len: usize) {
        write_bin_header(self.out, len);
    }

    /// Declare a map of `n` key/value pairs; the caller then emits `n`
    /// alternating keys and values.
    pub fn map_header(&mut self, n: usize) {
        write_map_header(self.out, n);
    }

    /// Declare an array of `n` elements; the caller then emits them.
    pub fn array_header(&mut self, n: usize) {
        write_array_header(self.out, n);
    }
}

// ---------------------------------------------------------------------------
// Pull-parser
// ---------------------------------------------------------------------------

/// Zero-copy pull-parser over a complete frame.
///
/// Typed accessors (`str`, `uint`, `map_header`, …) consume exactly one
/// value and return borrowed views into the input; [`Reader::skip_value`]
/// steps over a value of any shape without materializing it. Bounds are
/// checked against the remaining input before any access, exactly like the
/// tree decoder — a malicious length prefix cannot cause an over-read, and
/// nothing here allocates.
#[derive(Clone)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    /// Current offset from the start of the buffer.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left to parse.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        let b = *self.buf.get(self.pos).ok_or(DecodeError::Eof(self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        let remaining = self.buf.len() - self.pos;
        if n > remaining {
            return Err(DecodeError::LengthOverrun { offset: self.pos, len: n, remaining });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn be_u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn be_u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn be_u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Consume a map header, returning the number of key/value pairs.
    pub fn map_header(&mut self) -> Result<usize, DecodeError> {
        let off = self.pos;
        match self.u8()? {
            b @ 0x80..=0x8f => Ok((b & 0x0f) as usize),
            0xde => Ok(self.be_u16()? as usize),
            0xdf => Ok(self.be_u32()? as usize),
            _ => {
                self.pos = off;
                Err(DecodeError::Unexpected("map", off))
            }
        }
    }

    /// Consume an array header, returning the element count.
    pub fn array_header(&mut self) -> Result<usize, DecodeError> {
        let off = self.pos;
        match self.u8()? {
            b @ 0x90..=0x9f => Ok((b & 0x0f) as usize),
            0xdc => Ok(self.be_u16()? as usize),
            0xdd => Ok(self.be_u32()? as usize),
            _ => {
                self.pos = off;
                Err(DecodeError::Unexpected("array", off))
            }
        }
    }

    /// Consume a string, borrowing it from the input.
    pub fn str(&mut self) -> Result<&'a str, DecodeError> {
        let off = self.pos;
        let n = match self.u8()? {
            b @ 0xa0..=0xbf => (b & 0x1f) as usize,
            0xd9 => self.u8()? as usize,
            0xda => self.be_u16()? as usize,
            0xdb => self.be_u32()? as usize,
            _ => {
                self.pos = off;
                return Err(DecodeError::Unexpected("str", off));
            }
        };
        let data_off = self.pos;
        let bytes = self.take(n)?;
        std::str::from_utf8(bytes).map_err(|_| DecodeError::Utf8(data_off))
    }

    /// Consume a binary blob, borrowing it from the input.
    pub fn bin(&mut self) -> Result<&'a [u8], DecodeError> {
        let off = self.pos;
        let n = match self.u8()? {
            0xc4 => self.u8()? as usize,
            0xc5 => self.be_u16()? as usize,
            0xc6 => self.be_u32()? as usize,
            _ => {
                self.pos = off;
                return Err(DecodeError::Unexpected("bin", off));
            }
        };
        self.take(n)
    }

    /// Consume a non-negative integer of any encoded width.
    pub fn uint(&mut self) -> Result<u64, DecodeError> {
        let off = self.pos;
        let v = match self.u8()? {
            b @ 0x00..=0x7f => b as u64,
            0xcc => self.u8()? as u64,
            0xcd => self.be_u16()? as u64,
            0xce => self.be_u32()? as u64,
            0xcf => self.be_u64()?,
            // Signed encodings are accepted when the value is non-negative.
            0xd0 => {
                let i = self.u8()? as i8;
                if i < 0 {
                    self.pos = off;
                    return Err(DecodeError::Unexpected("uint", off));
                }
                i as u64
            }
            0xd1 => {
                let i = self.be_u16()? as i16;
                if i < 0 {
                    self.pos = off;
                    return Err(DecodeError::Unexpected("uint", off));
                }
                i as u64
            }
            0xd2 => {
                let i = self.be_u32()? as i32;
                if i < 0 {
                    self.pos = off;
                    return Err(DecodeError::Unexpected("uint", off));
                }
                i as u64
            }
            0xd3 => {
                let i = self.be_u64()? as i64;
                if i < 0 {
                    self.pos = off;
                    return Err(DecodeError::Unexpected("uint", off));
                }
                i as u64
            }
            _ => {
                self.pos = off;
                return Err(DecodeError::Unexpected("uint", off));
            }
        };
        Ok(v)
    }

    /// Consume a signed integer of any encoded width that fits in `i64`.
    pub fn int(&mut self) -> Result<i64, DecodeError> {
        let off = self.pos;
        let v = match self.u8()? {
            b @ 0x00..=0x7f => b as i64,
            b @ 0xe0..=0xff => b as i8 as i64,
            0xcc => self.u8()? as i64,
            0xcd => self.be_u16()? as i64,
            0xce => self.be_u32()? as i64,
            0xcf => {
                let u = self.be_u64()?;
                if u > i64::MAX as u64 {
                    self.pos = off;
                    return Err(DecodeError::Unexpected("int", off));
                }
                u as i64
            }
            0xd0 => self.u8()? as i8 as i64,
            0xd1 => self.be_u16()? as i16 as i64,
            0xd2 => self.be_u32()? as i32 as i64,
            0xd3 => self.be_u64()? as i64,
            _ => {
                self.pos = off;
                return Err(DecodeError::Unexpected("int", off));
            }
        };
        Ok(v)
    }

    /// Consume a boolean.
    pub fn boolean(&mut self) -> Result<bool, DecodeError> {
        let off = self.pos;
        match self.u8()? {
            0xc2 => Ok(false),
            0xc3 => Ok(true),
            _ => {
                self.pos = off;
                Err(DecodeError::Unexpected("bool", off))
            }
        }
    }

    /// Step over one complete value of any type without materializing it.
    ///
    /// Iterative (a pending-element counter instead of recursion) so hostile
    /// nesting depth cannot overflow the stack; every loop iteration
    /// consumes at least one input byte, so the walk is linear in the frame
    /// size regardless of declared container counts.
    pub fn skip_value(&mut self) -> Result<(), DecodeError> {
        let mut pending: u64 = 1;
        while pending > 0 {
            pending -= 1;
            let off = self.pos;
            let b = self.u8()?;
            match b {
                0x00..=0x7f | 0xe0..=0xff | 0xc0 | 0xc2 | 0xc3 => {}
                0x80..=0x8f => pending += 2 * (b & 0x0f) as u64,
                0x90..=0x9f => pending += (b & 0x0f) as u64,
                0xa0..=0xbf => {
                    self.take((b & 0x1f) as usize)?;
                }
                0xc1 => return Err(DecodeError::BadFormat(b, off)),
                0xc4 => {
                    let n = self.u8()? as usize;
                    self.take(n)?;
                }
                0xc5 => {
                    let n = self.be_u16()? as usize;
                    self.take(n)?;
                }
                0xc6 => {
                    let n = self.be_u32()? as usize;
                    self.take(n)?;
                }
                0xc7 => {
                    let n = self.u8()? as usize;
                    self.u8()?;
                    self.take(n)?;
                }
                0xc8 => {
                    let n = self.be_u16()? as usize;
                    self.u8()?;
                    self.take(n)?;
                }
                0xc9 => {
                    let n = self.be_u32()? as usize;
                    self.u8()?;
                    self.take(n)?;
                }
                0xca | 0xce | 0xd2 | 0xd6 => {
                    // f32 / u32 / i32 / fixext4 all carry 4 payload bytes
                    // (fixext adds its tag byte below).
                    let extra = if b == 0xd6 { 5 } else { 4 };
                    self.take(extra)?;
                }
                0xcb | 0xcf | 0xd3 | 0xd7 => {
                    let extra = if b == 0xd7 { 9 } else { 8 };
                    self.take(extra)?;
                }
                0xcc | 0xd0 => {
                    self.take(1)?;
                }
                0xcd | 0xd1 => {
                    self.take(2)?;
                }
                0xd4 => {
                    self.take(2)?;
                }
                0xd5 => {
                    self.take(3)?;
                }
                0xd8 => {
                    self.take(17)?;
                }
                0xd9 => {
                    let n = self.u8()? as usize;
                    self.take(n)?;
                }
                0xda => {
                    let n = self.be_u16()? as usize;
                    self.take(n)?;
                }
                0xdb => {
                    let n = self.be_u32()? as usize;
                    self.take(n)?;
                }
                0xdc => pending += self.be_u16()? as u64,
                0xdd => pending += self.be_u32()? as u64,
                0xde => pending += 2 * self.be_u16()? as u64,
                0xdf => pending += 2 * self.be_u32()? as u64,
            }
        }
        Ok(())
    }

    /// Skip one value and return the raw bytes it occupied.
    pub fn value_span(&mut self) -> Result<&'a [u8], DecodeError> {
        let start = self.pos;
        self.skip_value()?;
        Ok(&self.buf[start..self.pos])
    }
}

#[cfg(test)]
mod tests {
    use super::super::{encode, Value};
    use super::*;

    fn enc(v: &Value) -> Vec<u8> {
        encode(v)
    }

    #[test]
    fn bin_header_plus_payload_matches_bin() {
        // The split header/payload emit must be byte-identical to the
        // one-shot bin encoder at every length-format boundary.
        for len in [0usize, 1, 255, 256, 65535, 65536, 100_000] {
            let payload = vec![0xabu8; len];
            let mut split = Vec::new();
            {
                let mut w = Writer::new(&mut split);
                w.bin_header(len);
            }
            split.extend_from_slice(&payload);
            let mut whole = Vec::new();
            Writer::new(&mut whole).bin(&payload);
            assert_eq!(split, whole, "len {len}");
        }
    }

    #[test]
    fn writer_matches_value_encoder_scalars() {
        for u in [0u64, 1, 0x7f, 0x80, 0xff, 0x100, 0xffff, 0x1_0000, u32::MAX as u64, u64::MAX]
        {
            let mut buf = Vec::new();
            Writer::new(&mut buf).uint(u);
            assert_eq!(buf, enc(&Value::from(u)), "uint {u}");
        }
        for i in [-1i64, -32, -33, -128, -129, -32768, -32769, i32::MIN as i64, i64::MIN] {
            let mut buf = Vec::new();
            Writer::new(&mut buf).int(i);
            assert_eq!(buf, enc(&Value::Int(i)), "int {i}");
        }
        for s in ["", "x", &"y".repeat(31), &"z".repeat(32), &"w".repeat(256)] {
            let mut buf = Vec::new();
            Writer::new(&mut buf).str(s);
            assert_eq!(buf, enc(&Value::str(s)), "str len {}", s.len());
        }
        for n in [0usize, 1, 255, 256, 65536] {
            let mut buf = Vec::new();
            Writer::new(&mut buf).bin(&vec![0xAB; n]);
            assert_eq!(buf, enc(&Value::Bin(vec![0xAB; n])), "bin len {n}");
        }
        let mut buf = Vec::new();
        {
            let mut w = Writer::new(&mut buf);
            w.boolean(true);
            w.boolean(false);
            w.nil();
            w.f64(1.0);
        }
        let mut want = enc(&Value::Bool(true));
        want.extend(enc(&Value::Bool(false)));
        want.extend(enc(&Value::Nil));
        want.extend(enc(&Value::F64(1.0)));
        assert_eq!(buf, want);
    }

    #[test]
    fn reader_roundtrips_writer_output() {
        let mut buf = Vec::new();
        {
            let mut w = Writer::new(&mut buf);
            w.map_header(2);
            w.str("a");
            w.uint(300);
            w.str("b");
            w.array_header(3);
            w.int(-5);
            w.boolean(true);
            w.bin(b"xyz");
        }
        let mut r = Reader::new(&buf);
        assert_eq!(r.map_header().unwrap(), 2);
        assert_eq!(r.str().unwrap(), "a");
        assert_eq!(r.uint().unwrap(), 300);
        assert_eq!(r.str().unwrap(), "b");
        assert_eq!(r.array_header().unwrap(), 3);
        assert_eq!(r.int().unwrap(), -5);
        assert!(r.boolean().unwrap());
        assert_eq!(r.bin().unwrap(), b"xyz");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn type_mismatch_reports_offset_and_rewinds() {
        let mut buf = Vec::new();
        Writer::new(&mut buf).uint(7);
        let mut r = Reader::new(&buf);
        assert!(matches!(r.str(), Err(DecodeError::Unexpected("str", 0))));
        // Failed typed read leaves the cursor in place so the caller can
        // recover (e.g. skip the value instead).
        assert_eq!(r.pos(), 0);
        assert_eq!(r.uint().unwrap(), 7);
    }

    #[test]
    fn skip_value_steps_over_arbitrary_trees() {
        let v = Value::map(vec![
            ("a", Value::Array(vec![Value::Int(1), Value::str("two"), Value::Nil])),
            ("b", Value::map(vec![("c", Value::Bin(vec![9; 300]))])),
            ("d", Value::F32(2.5)),
            ("e", Value::Ext(5, vec![1, 2, 3, 4])),
        ]);
        let mut bytes = enc(&v);
        bytes.extend_from_slice(&[0x2a]); // trailing sentinel value (42)
        let mut r = Reader::new(&bytes);
        r.skip_value().unwrap();
        assert_eq!(r.uint().unwrap(), 42, "skip must land exactly on the next value");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn skip_value_truncated_input_errors_cleanly() {
        let v = Value::Array(vec![Value::str("hello"); 10]);
        let bytes = enc(&v);
        for cut in 1..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(r.skip_value().is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn skip_value_hostile_counts_bounded() {
        // array32 declaring 1M elements over a 5-byte buffer: linear walk,
        // clean error.
        let mut r = Reader::new(&[0xdd, 0x00, 0x0f, 0x42, 0x40]);
        assert!(r.skip_value().is_err());
        // map32 with a huge count.
        let mut r = Reader::new(&[0xdf, 0xff, 0xff, 0xff, 0xff]);
        assert!(r.skip_value().is_err());
    }

    #[test]
    fn value_span_returns_exact_slice() {
        let v = Value::Array(vec![Value::Int(1), Value::Int(2)]);
        let mut bytes = enc(&v);
        let inner_len = bytes.len();
        bytes.push(0x07);
        let mut r = Reader::new(&bytes);
        let span = r.value_span().unwrap();
        assert_eq!(span, &enc(&v)[..]);
        assert_eq!(span.len(), inner_len);
        assert_eq!(r.uint().unwrap(), 7);
    }
}
