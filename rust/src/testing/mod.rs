//! Minimal property-testing driver (offline stand-in for `proptest`):
//! runs a property over many seeded random cases and reports the failing
//! seed so a failure reproduces deterministically.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x5EED }
    }
}

/// Scale a base case count by the `RSDS_PROP_SCALE` environment variable
/// (an integer multiplier ≥ 1). PR CI runs the base counts; the scheduled
/// (nightly) workflow sets the multiplier to run the same suites much
/// harder without a code change. Unset/invalid values mean no scaling.
pub fn scaled_cases(base: usize) -> usize {
    std::env::var("RSDS_PROP_SCALE")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .map(|m| base * m.max(1))
        .unwrap_or(base)
}

/// Run `prop` over `cfg.cases` independently-seeded RNGs. The property
/// returns `Err(description)` to fail. Panics with the case seed on failure
/// (re-run with `PropConfig { cases: 1, seed }` to reproduce).
pub fn check(name: &str, cfg: PropConfig, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = root.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(why) = prop(&mut rng) {
            panic!("property {name:?} failed on case {case} (seed {case_seed:#x}): {why}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", PropConfig { cases: 10, seed: 1 }, |rng| {
            n += 1;
            let x = rng.gen_range(100);
            prop_assert!(x < 100, "x out of range: {x}");
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property \"failing\" failed")]
    fn failing_property_reports_seed() {
        check("failing", PropConfig { cases: 5, seed: 2 }, |rng| {
            let x = rng.gen_range(10);
            prop_assert!(x < 5, "x = {x}");
            Ok(())
        });
    }
}
