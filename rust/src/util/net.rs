//! Client-side connect hardening.
//!
//! With a thousand clients connecting at once (the fig. 9 shard-scaling
//! bench), the kernel's listen backlog (~128 by default) overflows and
//! late SYNs are refused or reset even though the server is healthy and
//! draining accepts as fast as it can. A bounded retry with backoff turns
//! that transient into a short stall instead of a hard failure; genuine
//! errors (unroutable address, permission) still fail on the first try.

use std::io;
use std::net::TcpStream;
use std::time::Duration;

/// Connection attempts before giving up (first try included).
const CONNECT_ATTEMPTS: u32 = 20;

/// First retry delay; doubles per retry up to [`BACKOFF_MAX`].
const BACKOFF_START: Duration = Duration::from_millis(10);

/// Backoff ceiling — total worst-case wait stays under ~1 s.
const BACKOFF_MAX: Duration = Duration::from_millis(50);

fn is_transient(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::ConnectionRefused
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::TimedOut
    )
}

/// `TcpStream::connect` with bounded retry on backlog-overflow transients
/// (refused / reset / timed out). Non-transient errors and exhaustion
/// return the last error.
pub fn connect_with_retry<A: std::net::ToSocketAddrs + Copy>(addr: A) -> io::Result<TcpStream> {
    let mut delay = BACKOFF_START;
    let mut last: Option<io::Error> = None;
    for attempt in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if is_transient(e.kind()) && attempt + 1 < CONNECT_ATTEMPTS => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(BACKOFF_MAX);
                last = Some(e);
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::Other, "connect retries exhausted")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn connects_to_live_listener_first_try() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let s = connect_with_retry(addr).unwrap();
        assert_eq!(s.peer_addr().unwrap(), addr);
    }

    #[test]
    fn refused_port_eventually_errors() {
        // Bind then drop to get a port that refuses connections.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = connect_with_retry(addr).expect_err("nothing is listening");
        assert!(is_transient(err.kind()), "unexpected kind {:?}", err.kind());
    }
}
