//! Deterministic pseudo-random number generation.
//!
//! Xoshiro256** seeded via SplitMix64 — the standard construction
//! (Blackman & Vigna). Used by the random scheduler (paper §III-E), the
//! task-graph generators and the property-testing driver. Determinism given
//! a seed is load-bearing: experiments are reproducible run-to-run and the
//! simulator's random scheduler can be replayed.

/// SplitMix64 step; used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is invalid for xoshiro; SplitMix64 cannot produce
        // four consecutive zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            return Rng { s: [1, 2, 3, 4] };
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` using Lemire's multiply-shift rejection method.
    /// Panics if `n == 0`.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Widening multiply rejection sampling — unbiased.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample from an exponential distribution with the given mean.
    /// Used by the simulator's task-duration jitter.
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // Inverse transform; (1 - u) avoids ln(0).
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Standard normal via Box–Muller (single value; the twin is discarded —
    /// simplicity over throughput, this is not on a hot path).
    pub fn normal(&mut self, mean: f64, stddev: f64) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        mean + stddev * z
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Split off an independently-seeded child generator (for parallel
    /// deterministic streams, e.g. one per simulated worker).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn gen_range_unbiased_rough() {
        // Chi-square-ish sanity: 6 buckets, 60k draws, each within 5% of 10k.
        let mut r = Rng::new(123);
        let mut counts = [0u32; 6];
        for _ in 0..60_000 {
            counts[r.gen_range(6) as usize] += 1;
        }
        for c in counts {
            assert!((9_500..=10_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exponential_mean_roughly_correct() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(3.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(77);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
