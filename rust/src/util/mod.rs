//! Small self-contained utilities: PRNG, statistics, timing, CLI parsing.
//!
//! The build environment is offline, so the usual crates (`rand`,
//! `criterion`'s stats, `clap`) are reimplemented here at the scale this
//! project needs. Each submodule is fully unit-tested.

pub mod cli;
pub mod net;
pub mod rng;
pub mod stats;
pub mod timing;

pub use net::connect_with_retry;
pub use rng::Rng;
pub use stats::Summary;
