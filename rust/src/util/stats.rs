//! Descriptive statistics for the benchmark harness and experiment reports:
//! mean, geometric mean (the paper's Table II metric), stddev, percentiles.

/// Summary statistics over a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Some(Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        })
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let idx = pos.floor() as usize;
    let frac = pos - idx as f64;
    if idx + 1 < sorted.len() {
        sorted[idx] * (1.0 - frac) + sorted[idx + 1] * frac
    } else {
        sorted[idx]
    }
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean — the aggregation the paper uses for Table II speedups.
/// Requires strictly positive inputs.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    assert!(xs.iter().all(|&x| x > 0.0), "geomean requires positive values");
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Format a duration in microseconds with an adaptive unit, for reports.
pub fn fmt_us(us: f64) -> String {
    if us < 1_000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1_000.0)
    } else {
        format!("{:.3}s", us / 1_000_000.0)
    }
}

/// Format a byte count with an adaptive unit.
pub fn fmt_bytes(b: u64) -> String {
    const KIB: u64 = 1024;
    const MIB: u64 = 1024 * 1024;
    const GIB: u64 = 1024 * 1024 * 1024;
    if b < KIB {
        format!("{b}B")
    } else if b < MIB {
        format!("{:.1}KiB", b as f64 / KIB as f64)
    } else if b < GIB {
        format!("{:.1}MiB", b as f64 / MIB as f64)
    } else {
        format!("{:.2}GiB", b as f64 / GIB as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // sample stddev of 1..5 = sqrt(2.5)
        assert!((s.stddev - 2.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]).unwrap();
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.9) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_paper_style() {
        // geomean of speedups: sqrt(2 * 0.5) = 1.0
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_us(12.34), "12.3µs");
        assert_eq!(fmt_us(12_340.0), "12.34ms");
        assert_eq!(fmt_us(1_234_000.0), "1.234s");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MiB");
    }
}
