//! Wall-clock timing helpers and a calibrated busy-wait.
//!
//! The busy-wait is how the real runtime emulates (a) task compute time for
//! the `merge`/`merge_slow` benchmarks (the paper's tasks burn CPU — they are
//! compute-bound, §VI) and (b) the CPython per-event overhead when the server
//! runs with the `python` runtime profile (`--emulate-python`). `sleep()`
//! would under-represent CPU contention, which is the very thing the paper
//! measures.

use std::time::{Duration, Instant};

/// Busy-spin for the given number of microseconds, consuming CPU.
/// Granularity is bounded by `Instant::now()` resolution (tens of ns).
#[inline]
pub fn busy_wait_us(us: u64) {
    if us == 0 {
        return;
    }
    let deadline = Instant::now() + Duration::from_micros(us);
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}

/// Time a closure, returning (result, elapsed µs).
pub fn time_us<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e6)
}

/// A monotonically increasing stopwatch anchored at construction.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed microseconds since start.
    pub fn elapsed_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_wait_takes_at_least_requested() {
        let (_, us) = time_us(|| busy_wait_us(500));
        assert!(us >= 500.0, "waited only {us}µs");
        // Upper bound is loose: CI machines stall, but 50x is a bug.
        assert!(us < 25_000.0, "waited {us}µs for 500µs request");
    }

    #[test]
    fn busy_wait_zero_fast() {
        let (_, us) = time_us(|| busy_wait_us(0));
        assert!(us < 1_000.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_us();
        busy_wait_us(100);
        let b = sw.elapsed_us();
        assert!(b >= a + 100);
    }
}
