//! Minimal command-line argument parser for the `rsds` binary and examples.
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order, options by name.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("missing value for option --{0}")]
    MissingValue(String),
    #[error("invalid value for --{key}: {value:?} ({reason})")]
    InvalidValue { key: String, value: String, reason: String },
    #[error("missing required option --{0}")]
    MissingRequired(String),
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    ///
    /// `value_opts` lists option names that take a value; anything else
    /// starting with `--` is a boolean flag. `--key=value` works for both
    /// (a flag given `=value` is treated as an option).
    pub fn parse<I, S>(raw: I, value_opts: &[&str]) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = raw.into_iter().map(Into::into).peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if value_opts.contains(&stripped) {
                    let v = iter
                        .next()
                        .ok_or_else(|| CliError::MissingValue(stripped.to_string()))?;
                    args.options.entry(stripped.to_string()).or_default().push(v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse directly from `std::env::args()` (skipping argv[0]).
    pub fn from_env(value_opts: &[&str]) -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::MissingRequired(name.to_string()))
    }

    /// Typed accessor with a default.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e: T::Err| CliError::InvalidValue {
                key: name.to_string(),
                value: s.to_string(),
                reason: e.to_string(),
            }),
        }
    }

    /// First positional (commonly the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], opts: &[&str]) -> Args {
        Args::parse(v.iter().copied(), opts).unwrap()
    }

    #[test]
    fn positional_and_subcommand() {
        let a = parse(&["serve", "extra"], &[]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn flags_and_options() {
        let a = parse(&["--verbose", "--port", "8786", "--name=w1"], &["port"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("port"), Some("8786"));
        assert_eq!(a.get("name"), Some("w1"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--workers", "24"], &["workers"]);
        assert_eq!(a.get_parsed_or("workers", 1usize).unwrap(), 24);
        assert_eq!(a.get_parsed_or("nodes", 7usize).unwrap(), 7);
    }

    #[test]
    fn invalid_typed_value_errors() {
        let a = parse(&["--workers", "many"], &["workers"]);
        assert!(a.get_parsed_or("workers", 1usize).is_err());
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(["--port"], &["port"]).unwrap_err();
        assert!(matches!(e, CliError::MissingValue(_)));
    }

    #[test]
    fn repeated_options_accumulate_last_wins() {
        let a = parse(&["--graph=merge-100", "--graph=tree-5"], &[]);
        assert_eq!(a.get_all("graph"), vec!["merge-100", "tree-5"]);
        assert_eq!(a.get("graph"), Some("tree-5"));
    }

    #[test]
    fn require_missing() {
        let a = parse(&[], &[]);
        assert!(matches!(a.require("addr"), Err(CliError::MissingRequired(_))));
    }
}
