//! Task graph representation — the core program model (paper §III-A).
//!
//! A task graph is a DAG whose vertices are tasks (functions operating on
//! input data, producing output data) and whose arcs are dependencies/data
//! transfers. The server, the schedulers, the workers and the simulator all
//! operate on this representation; the [`crate::graphgen`] module builds the
//! paper's benchmark graphs (§V, Table I) on top of it.

mod analysis;
mod graph;
mod payload;

pub use analysis::{
    critical_path_us, longest_path, max_width, replication_hints, total_transfer_bytes, GraphStats,
};
pub use graph::{GraphBuilder, GraphError, TaskGraph, TaskId, TaskSpec};
pub use payload::Payload;
