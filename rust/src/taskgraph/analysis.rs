//! Graph analysis: the statistics the paper reports per benchmark in
//! Table I (#T, #I, S, AD, LP) plus critical-path work, used by the
//! Table I bench and by the experiment harness to sanity-check generators.

use super::TaskGraph;
#[cfg(test)]
use super::TaskId;

/// Table I row for a graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    /// #T — number of tasks.
    pub n_tasks: usize,
    /// #I — number of dependency arcs.
    pub n_deps: usize,
    /// S — average task output size, KiB.
    pub avg_output_kib: f64,
    /// AD — average task duration, ms.
    pub avg_duration_ms: f64,
    /// LP — longest oriented path, counted in *arcs* (a single task = 0).
    pub longest_path: usize,
    /// Critical path length in µs (duration-weighted longest path); lower
    /// bound on any makespan.
    pub critical_path_us: u64,
}

impl GraphStats {
    pub fn of(g: &TaskGraph) -> GraphStats {
        let n = g.len();
        let total_out: u64 = g.tasks().iter().map(|t| t.output_size).sum();
        let total_dur: u64 = g.total_work_us();
        GraphStats {
            n_tasks: n,
            n_deps: g.n_deps(),
            avg_output_kib: total_out as f64 / n as f64 / 1024.0,
            avg_duration_ms: total_dur as f64 / n as f64 / 1000.0,
            longest_path: longest_path(g),
            critical_path_us: critical_path_us(g),
        }
    }

    /// Render like a Table I row.
    pub fn row(&self, name: &str) -> String {
        format!(
            "{:<28} {:>8} {:>8} {:>10.3} {:>10.3} {:>4}",
            name, self.n_tasks, self.n_deps, self.avg_output_kib, self.avg_duration_ms, self.longest_path
        )
    }
}

/// Longest oriented path in arcs. Single pass in topological (id) order.
pub fn longest_path(g: &TaskGraph) -> usize {
    let mut depth = vec![0usize; g.len()];
    let mut best = 0;
    for id in g.topo_order() {
        let t = g.task(id);
        let d = t
            .inputs
            .iter()
            .map(|i| depth[i.idx()] + 1)
            .max()
            .unwrap_or(0);
        depth[id.idx()] = d;
        best = best.max(d);
    }
    best
}

/// Duration-weighted critical path (µs), the classic makespan lower bound.
pub fn critical_path_us(g: &TaskGraph) -> u64 {
    let mut finish = vec![0u64; g.len()];
    let mut best = 0;
    for id in g.topo_order() {
        let t = g.task(id);
        let start = t.inputs.iter().map(|i| finish[i.idx()]).max().unwrap_or(0);
        finish[id.idx()] = start + t.duration_us;
        best = best.max(finish[id.idx()]);
    }
    best
}

/// Width estimate: maximum number of tasks whose depth equals each level —
/// a cheap proxy for available parallelism used in reports.
pub fn max_width(g: &TaskGraph) -> usize {
    let mut depth = vec![0usize; g.len()];
    for id in g.topo_order() {
        let t = g.task(id);
        depth[id.idx()] = t.inputs.iter().map(|i| depth[i.idx()] + 1).max().unwrap_or(0);
    }
    let max_d = depth.iter().copied().max().unwrap_or(0);
    let mut counts = vec![0usize; max_d + 1];
    for d in depth {
        counts[d] += 1;
    }
    counts.into_iter().max().unwrap_or(0)
}

/// Which outputs are worth proactive k-replication (the PR 8 object-store
/// policy): *hot* outputs — fan-out of at least `fanout` consumers, whose
/// loss would stall many tasks at once — and every task on one
/// duration-weighted critical path, whose loss would stall the whole run.
/// Both the reactor (`server/reactor.rs`) and the simulator
/// (`sim/engine.rs`) call this, so the two stay policy-identical and the
/// parity suite can compare their recovery behavior.
pub fn replication_hints(g: &TaskGraph, fanout: u32) -> Vec<bool> {
    let mut hint = vec![false; g.len()];
    for id in g.topo_order() {
        if g.consumers(id).len() >= fanout as usize {
            hint[id.idx()] = true;
        }
    }
    // Forward finish-time pass (as in `critical_path_us`), then walk one
    // critical chain backwards from the latest-finishing task.
    let mut finish = vec![0u64; g.len()];
    let mut tail = None;
    for id in g.topo_order() {
        let t = g.task(id);
        let start = t.inputs.iter().map(|i| finish[i.idx()]).max().unwrap_or(0);
        finish[id.idx()] = start + t.duration_us;
        if tail.map_or(true, |b: super::TaskId| finish[id.idx()] > finish[b.idx()]) {
            tail = Some(id);
        }
    }
    let mut cur = tail;
    while let Some(id) = cur {
        hint[id.idx()] = true;
        cur = g.task(id).inputs.iter().copied().max_by_key(|i| finish[i.idx()]);
    }
    hint
}

/// Sum of all output sizes along dependency arcs — total bytes that would
/// move if every dependency crossed the network (upper bound on traffic).
pub fn total_transfer_bytes(g: &TaskGraph) -> u64 {
    let mut total = 0u64;
    for id in g.topo_order() {
        let n_consumers = g.consumers(id).len() as u64;
        total += g.task(id).output_size * n_consumers;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::{GraphBuilder, Payload};

    fn chain(n: usize) -> TaskGraph {
        let mut b = GraphBuilder::new();
        let mut prev: Option<TaskId> = None;
        for i in 0..n {
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            prev = Some(b.add(format!("c{i}"), inputs, 1000, 2048, Payload::BusyWait));
        }
        b.build("chain").unwrap()
    }

    #[test]
    fn chain_stats() {
        let g = chain(5);
        let s = GraphStats::of(&g);
        assert_eq!(s.n_tasks, 5);
        assert_eq!(s.n_deps, 4);
        assert_eq!(s.longest_path, 4);
        assert_eq!(s.critical_path_us, 5_000);
        assert!((s.avg_output_kib - 2.0).abs() < 1e-9);
        assert!((s.avg_duration_ms - 1.0).abs() < 1e-9);
        assert_eq!(max_width(&g), 1);
    }

    #[test]
    fn replication_hints_flag_fanout_and_critical_chain() {
        // Diamond with a slow left leg: a → {b slow, c fast} → d.
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 100, 8, Payload::BusyWait);
        let slow = b.add("b", vec![a], 10_000, 8, Payload::BusyWait);
        let fast = b.add("c", vec![a], 10, 8, Payload::BusyWait);
        let d = b.add("d", vec![slow, fast], 100, 8, Payload::BusyWait);
        let g = b.build("diamond").unwrap();
        // Fan-out threshold 2: only `a` (two consumers) is hot; the
        // critical chain a → slow → d is flagged too; `fast` is not.
        let hints = replication_hints(&g, 2);
        assert_eq!(
            hints,
            vec![true, true, false, true],
            "hot root + critical chain, fast leg excluded"
        );
        // Threshold 1 marks everything with at least one consumer, plus
        // the chain (which covers the sink).
        assert_eq!(replication_hints(&g, 1), vec![true; 4]);
        let _ = d;
    }

    #[test]
    fn single_task_lp_zero() {
        let g = chain(1);
        assert_eq!(longest_path(&g), 0);
        assert_eq!(critical_path_us(&g), 1000);
    }

    #[test]
    fn fan_out_in() {
        // root -> 10 mids -> sink : LP = 2, width = 10
        let mut b = GraphBuilder::new();
        let r = b.add("r", vec![], 10, 1, Payload::NoOp);
        let mids: Vec<TaskId> =
            (0..10).map(|i| b.add(format!("m{i}"), vec![r], 100, 1, Payload::BusyWait)).collect();
        b.add("s", mids, 10, 1, Payload::MergeInputs);
        let g = b.build("fan").unwrap();
        assert_eq!(longest_path(&g), 2);
        assert_eq!(max_width(&g), 10);
        assert_eq!(critical_path_us(&g), 120);
        // transfer upper bound: root output consumed 10× + 10 mids consumed 1×
        assert_eq!(total_transfer_bytes(&g), 10 + 10);
    }

    #[test]
    fn critical_path_picks_heavier_branch() {
        let mut b = GraphBuilder::new();
        let r = b.add("r", vec![], 0, 1, Payload::NoOp);
        let fast = b.add("fast", vec![r], 10, 1, Payload::BusyWait);
        let slow = b.add("slow", vec![r], 10_000, 1, Payload::BusyWait);
        b.add("join", vec![fast, slow], 5, 1, Payload::MergeInputs);
        let g = b.build("branch").unwrap();
        assert_eq!(critical_path_us(&g), 10_005);
    }
}
