//! DAG structure, validation and traversal.

use super::Payload;
use std::collections::HashMap;

/// Dense task identifier, unique within one graph (index into `tasks`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u32);

impl TaskId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// One task: a function with inputs, an expected duration (what the paper's
/// Table I reports as AD) and an output size (Table I's S).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub id: TaskId,
    /// Dask-style string key, e.g. `"merge-ab12-17"`. Used on the wire.
    pub key: String,
    /// Dependencies: tasks whose outputs this task consumes.
    pub inputs: Vec<TaskId>,
    /// Expected pure compute duration in µs (excludes all overheads).
    pub duration_us: u64,
    /// Output size in bytes placed in the producing worker's data store.
    pub output_size: u64,
    pub payload: Payload,
    /// Core slots the task occupies while executing (dslab-dag-style
    /// resource requirement); `1` for ordinary tasks. A task can only be
    /// placed on a worker with `ncores >= cores`.
    pub cores: u32,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum GraphError {
    #[error("task {0} has id mismatching its position {1}")]
    IdMismatch(TaskId, usize),
    #[error("task {task} depends on unknown task {dep}")]
    UnknownDep { task: TaskId, dep: TaskId },
    #[error("task {task} depends on itself")]
    SelfDep { task: TaskId },
    #[error("task {task} lists dependency {dep} twice")]
    DupDep { task: TaskId, dep: TaskId },
    #[error("graph contains a cycle through task {0}")]
    Cycle(TaskId),
    #[error("duplicate task key {0:?}")]
    DupKey(String),
    #[error("graph is empty")]
    Empty,
}

/// An immutable task graph.
///
/// Construction enforces a *topological id order*: every dependency id is
/// smaller than the depending task's id. All generators naturally produce
/// graphs in this order, it makes cycle-freedom a local check, and the
/// schedulers/simulator exploit it (a plain id-order scan is a topological
/// order).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskGraph {
    pub name: String,
    tasks: Vec<TaskSpec>,
    /// consumers[i] = tasks that consume task i's output (reverse arcs).
    consumers: Vec<Vec<TaskId>>,
    n_deps: usize,
}

impl TaskGraph {
    /// Build and validate a graph from specs.
    pub fn new(name: impl Into<String>, tasks: Vec<TaskSpec>) -> Result<TaskGraph, GraphError> {
        if tasks.is_empty() {
            return Err(GraphError::Empty);
        }
        let n = tasks.len();
        let mut consumers: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        let mut n_deps = 0usize;
        let mut keys: HashMap<&str, TaskId> = HashMap::with_capacity(n);
        for (pos, t) in tasks.iter().enumerate() {
            if t.id.idx() != pos {
                return Err(GraphError::IdMismatch(t.id, pos));
            }
            if keys.insert(&t.key, t.id).is_some() {
                return Err(GraphError::DupKey(t.key.clone()));
            }
            let mut seen = Vec::with_capacity(t.inputs.len());
            for &d in &t.inputs {
                if d == t.id {
                    return Err(GraphError::SelfDep { task: t.id });
                }
                if d.idx() >= n {
                    return Err(GraphError::UnknownDep { task: t.id, dep: d });
                }
                if d.idx() > pos {
                    // Forward reference ⇒ not in topological id order; since
                    // we require that order, report it as a cycle-class error.
                    return Err(GraphError::Cycle(t.id));
                }
                if seen.contains(&d) {
                    return Err(GraphError::DupDep { task: t.id, dep: d });
                }
                seen.push(d);
                consumers[d.idx()].push(t.id);
                n_deps += 1;
            }
        }
        Ok(TaskGraph { name: name.into(), tasks, consumers, n_deps })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total number of dependency arcs (Table I's #I).
    pub fn n_deps(&self) -> usize {
        self.n_deps
    }

    #[inline]
    pub fn task(&self, id: TaskId) -> &TaskSpec {
        &self.tasks[id.idx()]
    }

    pub fn tasks(&self) -> &[TaskSpec] {
        &self.tasks
    }

    /// Tasks consuming `id`'s output.
    #[inline]
    pub fn consumers(&self, id: TaskId) -> &[TaskId] {
        &self.consumers[id.idx()]
    }

    /// Ids in topological order (== id order by the construction invariant).
    pub fn topo_order(&self) -> impl Iterator<Item = TaskId> + '_ {
        (0..self.tasks.len() as u32).map(TaskId)
    }

    /// Tasks with no dependencies (initially ready).
    pub fn roots(&self) -> Vec<TaskId> {
        self.tasks
            .iter()
            .filter(|t| t.inputs.is_empty())
            .map(|t| t.id)
            .collect()
    }

    /// Tasks whose output nobody consumes (the graph's results, gathered by
    /// the client).
    pub fn sinks(&self) -> Vec<TaskId> {
        (0..self.tasks.len())
            .filter(|&i| self.consumers[i].is_empty())
            .map(|i| TaskId(i as u32))
            .collect()
    }

    /// Total pure compute time across all tasks, µs (lower bound on
    /// 1-worker makespan).
    pub fn total_work_us(&self) -> u64 {
        self.tasks.iter().map(|t| t.duration_us).sum()
    }

    /// Whether any payload needs the PJRT runtime.
    pub fn needs_runtime(&self) -> bool {
        self.tasks.iter().any(|t| t.payload.needs_runtime())
    }

    /// Largest per-task `cores` requirement (1 for a homogeneous graph).
    pub fn max_cores(&self) -> u32 {
        self.tasks.iter().map(|t| t.cores).max().unwrap_or(1).max(1)
    }

    /// Append a validated batch of tasks to an existing graph (the
    /// `submit-extend` op): ids continue densely from `len()`, dependencies
    /// may reference any lower id (including tasks of earlier epochs), keys
    /// must be unique against the whole graph. `consumers` and `n_deps`
    /// grow accordingly; existing tasks are never mutated, so ids, keys and
    /// the topological id-order invariant all survive extension.
    pub fn extend(&mut self, new_tasks: Vec<TaskSpec>) -> Result<(), GraphError> {
        if new_tasks.is_empty() {
            return Err(GraphError::Empty);
        }
        let base = self.tasks.len();
        let total = base + new_tasks.len();
        // Validate the batch fully before mutating anything: a rejected
        // extension must leave the graph exactly as it was.
        {
            let mut keys: HashMap<&str, TaskId> = HashMap::with_capacity(total);
            for t in &self.tasks {
                keys.insert(&t.key, t.id);
            }
            for (off, t) in new_tasks.iter().enumerate() {
                let pos = base + off;
                if t.id.idx() != pos {
                    return Err(GraphError::IdMismatch(t.id, pos));
                }
                if keys.insert(&t.key, t.id).is_some() {
                    return Err(GraphError::DupKey(t.key.clone()));
                }
                let mut seen = Vec::with_capacity(t.inputs.len());
                for &d in &t.inputs {
                    if d == t.id {
                        return Err(GraphError::SelfDep { task: t.id });
                    }
                    if d.idx() >= total {
                        return Err(GraphError::UnknownDep { task: t.id, dep: d });
                    }
                    if d.idx() > pos {
                        return Err(GraphError::Cycle(t.id));
                    }
                    if seen.contains(&d) {
                        return Err(GraphError::DupDep { task: t.id, dep: d });
                    }
                    seen.push(d);
                }
            }
        }
        self.consumers.resize(total, Vec::new());
        for t in &new_tasks {
            for &d in &t.inputs {
                self.consumers[d.idx()].push(t.id);
                self.n_deps += 1;
            }
        }
        self.tasks.extend(new_tasks);
        Ok(())
    }
}

/// Convenience builder used by generators and tests.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    tasks: Vec<TaskSpec>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a task; its id is its position. Panics on forward deps at
    /// build time (callers construct in topo order by design).
    pub fn add(
        &mut self,
        key: impl Into<String>,
        inputs: Vec<TaskId>,
        duration_us: u64,
        output_size: u64,
        payload: Payload,
    ) -> TaskId {
        self.add_with_cores(key, inputs, duration_us, output_size, payload, 1)
    }

    /// [`GraphBuilder::add`] with an explicit `cores` requirement.
    pub fn add_with_cores(
        &mut self,
        key: impl Into<String>,
        inputs: Vec<TaskId>,
        duration_us: u64,
        output_size: u64,
        payload: Payload,
        cores: u32,
    ) -> TaskId {
        let id = TaskId(self.tasks.len() as u32);
        self.tasks.push(TaskSpec {
            id,
            key: key.into(),
            inputs,
            duration_us,
            output_size,
            payload,
            cores: cores.max(1),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn build(self, name: impl Into<String>) -> Result<TaskGraph, GraphError> {
        TaskGraph::new(name, self.tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: u32, inputs: Vec<u32>) -> TaskSpec {
        TaskSpec {
            id: TaskId(id),
            key: format!("t-{id}"),
            inputs: inputs.into_iter().map(TaskId).collect(),
            duration_us: 10,
            output_size: 100,
            payload: Payload::NoOp,
            cores: 1,
        }
    }

    #[test]
    fn diamond_graph_valid() {
        let g = TaskGraph::new(
            "diamond",
            vec![t(0, vec![]), t(1, vec![0]), t(2, vec![0]), t(3, vec![1, 2])],
        )
        .unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.n_deps(), 4);
        assert_eq!(g.roots(), vec![TaskId(0)]);
        assert_eq!(g.sinks(), vec![TaskId(3)]);
        assert_eq!(g.consumers(TaskId(0)), &[TaskId(1), TaskId(2)]);
        assert_eq!(g.total_work_us(), 40);
    }

    #[test]
    fn rejects_empty() {
        assert_eq!(TaskGraph::new("e", vec![]).unwrap_err(), GraphError::Empty);
    }

    #[test]
    fn rejects_forward_dep_as_cycle() {
        let e = TaskGraph::new("c", vec![t(0, vec![1]), t(1, vec![])]).unwrap_err();
        assert_eq!(e, GraphError::Cycle(TaskId(0)));
    }

    #[test]
    fn rejects_self_dep() {
        let e = TaskGraph::new("s", vec![t(0, vec![0])]).unwrap_err();
        assert_eq!(e, GraphError::SelfDep { task: TaskId(0) });
    }

    #[test]
    fn rejects_unknown_dep() {
        let e = TaskGraph::new("u", vec![t(0, vec![]), t(1, vec![7])]).unwrap_err();
        assert_eq!(e, GraphError::UnknownDep { task: TaskId(1), dep: TaskId(7) });
    }

    #[test]
    fn rejects_dup_dep_and_dup_key() {
        let e = TaskGraph::new("d", vec![t(0, vec![]), t(1, vec![0, 0])]).unwrap_err();
        assert_eq!(e, GraphError::DupDep { task: TaskId(1), dep: TaskId(0) });

        let mut a = t(0, vec![]);
        let mut b = t(1, vec![]);
        a.key = "same".into();
        b.key = "same".into();
        let e = TaskGraph::new("k", vec![a, b]).unwrap_err();
        assert_eq!(e, GraphError::DupKey("same".into()));
    }

    #[test]
    fn rejects_id_position_mismatch() {
        let e = TaskGraph::new("m", vec![t(5, vec![])]).unwrap_err();
        assert_eq!(e, GraphError::IdMismatch(TaskId(5), 0));
    }

    #[test]
    fn extend_appends_and_grows_consumers() {
        let mut g = TaskGraph::new("x", vec![t(0, vec![]), t(1, vec![0])]).unwrap();
        g.extend(vec![t(2, vec![0]), t(3, vec![1, 2])]).unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.n_deps(), 4);
        assert_eq!(g.consumers(TaskId(0)), &[TaskId(1), TaskId(2)]);
        assert_eq!(g.consumers(TaskId(1)), &[TaskId(3)]);
        assert_eq!(g.sinks(), vec![TaskId(3)]);
    }

    #[test]
    fn extend_rejects_bad_batches_without_mutation() {
        let mut g = TaskGraph::new("x", vec![t(0, vec![])]).unwrap();
        let snapshot = g.clone();
        // Wrong id (must continue densely from len()).
        assert_eq!(g.extend(vec![t(5, vec![])]).unwrap_err(), GraphError::IdMismatch(TaskId(5), 1));
        // Duplicate key against the base graph.
        let mut dup = t(1, vec![]);
        dup.key = "t-0".into();
        assert_eq!(g.extend(vec![dup]).unwrap_err(), GraphError::DupKey("t-0".into()));
        // Forward reference within the batch.
        assert_eq!(g.extend(vec![t(1, vec![2]), t(2, vec![])]).unwrap_err(), GraphError::Cycle(TaskId(1)));
        // Unknown dep beyond the extended range.
        assert_eq!(
            g.extend(vec![t(1, vec![9])]).unwrap_err(),
            GraphError::UnknownDep { task: TaskId(1), dep: TaskId(9) }
        );
        // Empty batch.
        assert_eq!(g.extend(vec![]).unwrap_err(), GraphError::Empty);
        assert_eq!(g, snapshot, "failed extension must not mutate the graph");
    }

    #[test]
    fn builder_cores_default_and_override() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 5, 10, Payload::NoOp);
        let c = b.add_with_cores("c", vec![a], 5, 10, Payload::NoOp, 4);
        let g = b.build("g").unwrap();
        assert_eq!(g.task(a).cores, 1);
        assert_eq!(g.task(c).cores, 4);
        assert_eq!(g.max_cores(), 4);
    }

    #[test]
    fn builder_roundtrip() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", vec![], 5, 10, Payload::NoOp);
        let c = b.add("c", vec![a], 5, 10, Payload::MergeInputs);
        let g = b.build("g").unwrap();
        assert_eq!(g.len(), 2);
        assert_eq!(g.task(c).inputs, vec![a]);
    }
}
