//! Task payloads — what a worker actually *executes* for a task.
//!
//! In Dask a task carries a pickled Python function; here a task carries one
//! of a closed set of payload kinds. The compute-bound benchmark families
//! (merge, merge_slow, tree, bag, groupby, join) burn CPU for their measured
//! duration; the array families (xarray, numpy) execute AOT-compiled
//! JAX/Pallas kernels through PJRT; the text families (vectorizer, wordbag)
//! run a Rust text-processing pipeline. The simulator only reads
//! `duration_us` / `output_size` and never executes payloads.

/// Executable payload of a task.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Produce `output_size` bytes instantly (graph-structure benchmarks,
    /// zero-cost merge nodes).
    NoOp,
    /// Burn CPU for the task's `duration_us` (compute-bound tasks; §VI says
    /// the benchmarks are compute-bound, so busy-wait rather than sleep).
    BusyWait,
    /// Run the `partition_reduce` Pallas kernel (artifact
    /// `partition_reduce.hlo.txt`) on a synthetic `(rows, cols)` f32
    /// partition seeded with `seed` — xarray/numpy-style aggregation step.
    HloReduce { rows: u32, cols: u32, seed: u64 },
    /// Run the `numpy_step` artifact: tiled transpose+add+reduce on an
    /// `(n, n)` partition — the numpy benchmark's per-partition op.
    HloTranspose { n: u32, seed: u64 },
    /// Run the `feature_hash` Pallas kernel on `n_tokens` synthetic token
    /// ids hashed into `buckets` counts — the vectorizer benchmark.
    HloHash { n_tokens: u32, buckets: u32, seed: u64 },
    /// Rust text pipeline: normalize, correct, count, extract features over
    /// `n_docs` synthetic documents — the wordbag benchmark.
    WordBag { n_docs: u32, seed: u64 },
    /// Concatenate/merge the inputs (aggregation/merge nodes).
    MergeInputs,
}

impl Payload {
    /// Whether executing this payload requires the PJRT runtime (and hence
    /// built artifacts).
    pub fn needs_runtime(&self) -> bool {
        matches!(
            self,
            Payload::HloReduce { .. } | Payload::HloTranspose { .. } | Payload::HloHash { .. }
        )
    }

    /// Artifact file stem this payload executes, if any.
    pub fn artifact(&self) -> Option<&'static str> {
        match self {
            Payload::HloReduce { .. } => Some("partition_reduce"),
            Payload::HloTranspose { .. } => Some("numpy_step"),
            Payload::HloHash { .. } => Some("feature_hash"),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_requirements() {
        assert!(!Payload::NoOp.needs_runtime());
        assert!(!Payload::BusyWait.needs_runtime());
        assert!(!Payload::WordBag { n_docs: 1, seed: 0 }.needs_runtime());
        assert!(Payload::HloReduce { rows: 8, cols: 128, seed: 0 }.needs_runtime());
        assert!(Payload::HloHash { n_tokens: 64, buckets: 128, seed: 0 }.needs_runtime());
    }

    #[test]
    fn artifacts_named() {
        assert_eq!(Payload::HloReduce { rows: 1, cols: 1, seed: 0 }.artifact(), Some("partition_reduce"));
        assert_eq!(Payload::HloTranspose { n: 4, seed: 0 }.artifact(), Some("numpy_step"));
        assert_eq!(Payload::MergeInputs.artifact(), None);
    }
}
