//! Experiment metrics: makespan records, speedup tables, AOT series and
//! CSV export for the figure-regenerating benches.

use crate::util::stats::{fmt_us, geomean};
use std::io::Write;

/// One measured benchmark configuration (a point in the paper's figures).
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    /// Benchmark name (e.g. `merge-100K`).
    pub benchmark: String,
    /// Server implementation: `rsds` | `dask`.
    pub server: String,
    /// Scheduler: `ws` | `random` | `dask-ws`.
    pub scheduler: String,
    pub n_workers: usize,
    pub n_nodes: usize,
    /// Averaged makespan, µs.
    pub makespan_us: f64,
    /// Number of repetitions averaged.
    pub reps: usize,
    /// Average overhead per task (makespan / #tasks), µs — §VI-D's AOT.
    pub aot_us: f64,
}

impl Measurement {
    pub fn csv_header() -> &'static str {
        "benchmark,server,scheduler,n_workers,n_nodes,makespan_us,reps,aot_us"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{:.1},{},{:.3}",
            self.benchmark,
            self.server,
            self.scheduler,
            self.n_workers,
            self.n_nodes,
            self.makespan_us,
            self.reps,
            self.aot_us
        )
    }
}

/// Write measurements as CSV (one figure's data series).
pub fn write_csv(path: &str, rows: &[Measurement]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", Measurement::csv_header())?;
    for r in rows {
        writeln!(f, "{}", r.to_csv())?;
    }
    Ok(())
}

/// Speedup of `test` over `baseline` on the same benchmark/cluster
/// (baseline/test — >1 means `test` is faster), as in Figs 2–4 and 6.
pub fn speedup(baseline: &Measurement, test: &Measurement) -> f64 {
    assert_eq!(baseline.benchmark, test.benchmark, "speedup across different benchmarks");
    assert_eq!(baseline.n_workers, test.n_workers);
    baseline.makespan_us / test.makespan_us
}

/// Geometric-mean speedup over a set of benchmarks (the paper's Table II).
pub fn geomean_speedup(pairs: &[(Measurement, Measurement)]) -> f64 {
    let speedups: Vec<f64> = pairs.iter().map(|(b, t)| speedup(b, t)).collect();
    geomean(&speedups)
}

/// Pretty-print a figure-style series block.
pub fn print_series(title: &str, rows: &[Measurement]) {
    println!("== {title} ==");
    println!(
        "{:<28} {:>8} {:>10} {:>14} {:>10}",
        "benchmark", "workers", "sched", "makespan", "AOT/task"
    );
    for r in rows {
        println!(
            "{:<28} {:>8} {:>10} {:>14} {:>10}",
            r.benchmark,
            r.n_workers,
            r.scheduler,
            fmt_us(r.makespan_us),
            fmt_us(r.aot_us)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(bench: &str, server: &str, sched: &str, workers: usize, makespan: f64) -> Measurement {
        Measurement {
            benchmark: bench.into(),
            server: server.into(),
            scheduler: sched.into(),
            n_workers: workers,
            n_nodes: workers / 24,
            makespan_us: makespan,
            reps: 5,
            aot_us: makespan / 100.0,
        }
    }

    #[test]
    fn speedup_direction() {
        let dask = m("merge-10K", "dask", "ws", 24, 2_000_000.0);
        let rsds = m("merge-10K", "rsds", "ws", 24, 1_000_000.0);
        assert!((speedup(&dask, &rsds) - 2.0).abs() < 1e-12);
        assert!((speedup(&rsds, &dask) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_speedup_table2_style() {
        let pairs = vec![
            (m("a", "dask", "ws", 24, 4.0), m("a", "rsds", "ws", 24, 2.0)), // 2×
            (m("b", "dask", "ws", 24, 1.0), m("b", "rsds", "ws", 24, 2.0)), // 0.5×
        ];
        assert!((geomean_speedup(&pairs) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn speedup_rejects_mismatched_benchmarks() {
        let a = m("a", "dask", "ws", 24, 1.0);
        let b = m("b", "rsds", "ws", 24, 1.0);
        speedup(&a, &b);
    }

    #[test]
    fn csv_roundtrip_format() {
        let row = m("merge-10K", "rsds", "random", 168, 123_456.7);
        let csv = row.to_csv();
        assert!(csv.starts_with("merge-10K,rsds,random,168,7,123456.7,5,"));
        let tmp = std::env::temp_dir().join("rsds_metrics_test.csv");
        write_csv(tmp.to_str().unwrap(), &[row]).unwrap();
        let content = std::fs::read_to_string(&tmp).unwrap();
        assert!(content.starts_with(Measurement::csv_header()));
        assert_eq!(content.lines().count(), 2);
        std::fs::remove_file(tmp).ok();
    }
}
