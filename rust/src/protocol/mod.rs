//! Wire protocol: framed MessagePack messages between client, server and
//! workers (paper §III-B/§IV-B).
//!
//! Dask's protocol is MessagePack message dictionaries over TCP; the paper's
//! §IV-B modification keeps message structure static so a statically-typed
//! server can decode it — this implementation follows that simplified-
//! encoding design: every message is one msgpack map with a fixed `"op"`
//! discriminant and statically-known fields (no dynamic fragmenting).
//!
//! Framing is an 8-byte little-endian length prefix followed by the msgpack
//! body (`frame.rs`). [`Msg`] is the typed message set; `codec.rs` converts
//! between [`Msg`] and bytes and carries the task-graph encoding used by
//! `SubmitGraph`.
//!
//! The per-task hot path (assignment, `task-finished`, steal traffic, data
//! placement) is zero-copy end to end: [`encode_msg_into`] streams into a
//! reused buffer, [`decode_msg`] pull-parses the frame without allocating
//! field names, [`FrameWriter`]/[`FrameReader`] reuse one I/O buffer per
//! connection, and [`append_frame`] lets the server coalesce many frames
//! into one write. The owned-`Value` codec survives as the cold path
//! (`submit-graph`, registration) and as the byte-identical reference
//! ([`encode_msg_value`]/[`decode_msg_value`]) in tests. `docs/protocol.md`
//! documents the full wire format.

mod codec;
mod frame;
mod messages;

pub use codec::{
    decode_msg, decode_msg_value, encode_compute_task_into, encode_data_frame_head,
    encode_data_frame_tail, encode_fetch_many_into, encode_msg, encode_msg_into,
    encode_msg_value, graph_from_value, graph_to_value, peek_op, CodecError, ComputeTaskParts,
    ComputeTaskView, DataFrameParts, InputsIter, TaskInputRef,
};
pub use frame::{
    append_frame, append_frame_with, read_frame, write_frame, FrameAccumulator, FrameError,
    FrameReader, FrameWriter, NbRead, MAX_FRAME_LEN,
};
pub use messages::{
    Msg, RunId, TaskFinishedInfo, TaskInputLoc, FETCH_FAILED_PREFIX, RECOVERY_EXHAUSTED_REASON,
};
