//! Wire protocol: framed MessagePack messages between client, server and
//! workers (paper §III-B/§IV-B).
//!
//! Dask's protocol is MessagePack message dictionaries over TCP; the paper's
//! §IV-B modification keeps message structure static so a statically-typed
//! server can decode it — this implementation follows that simplified-
//! encoding design: every message is one msgpack map with a fixed `"op"`
//! discriminant and statically-known fields (no dynamic fragmenting).
//!
//! Framing is an 8-byte little-endian length prefix followed by the msgpack
//! body ([`frame`]). [`Msg`] is the typed message set; [`codec`] converts
//! between [`Msg`] and bytes and carries the task-graph encoding used by
//! `SubmitGraph`.

mod codec;
mod frame;
mod messages;

pub use codec::{decode_msg, encode_msg, graph_from_value, graph_to_value, CodecError};
pub use frame::{read_frame, write_frame, FrameError, MAX_FRAME_LEN};
pub use messages::{Msg, RunId, TaskFinishedInfo, TaskInputLoc};
