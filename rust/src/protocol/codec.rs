//! Msg ⇄ msgpack conversion, including the task-graph encoding carried by
//! `submit-graph`. Static message structure throughout (§IV-B).
//!
//! Two codecs share one wire format:
//!
//! - **Streaming (production)** — [`encode_msg_into`] emits every message
//!   straight into a caller-reused buffer via [`Writer`], and [`decode_msg`]
//!   pull-parses the frame bytes via [`Reader`] without ever allocating a
//!   field-name string. The per-task hot-path messages (`compute-task`,
//!   `task-finished`, steal request/answer, data placement) cross this path
//!   with zero codec-side heap allocations; [`ComputeTaskView`] additionally
//!   offers a fully borrowed decode of the assignment message.
//! - **`Value` tree (cold path + reference)** — `submit-graph` and the
//!   registration ops decode through the owned [`Value`] tree (their
//!   payloads are structurally dynamic and per-connection/run, not
//!   per-task), and [`encode_msg_value`]/[`decode_msg_value`] keep the full
//!   tree codec alive as the byte-identical reference the round-trip
//!   property tests compare against.
//!
//! Canonical ordering: every message is one msgpack map whose keys are
//! emitted in sorted (byte-lexicographic) order — exactly what the
//! `BTreeMap`-backed `Value` tree produces — so the two codecs are
//! byte-identical for every message.

use super::messages::{Msg, RunId, TaskFinishedInfo, TaskInputLoc, MAX_ALT_ADDRS};
use crate::msgpack::{decode, encode, encode_into, DecodeError, Reader, Value, Writer};
use crate::taskgraph::{GraphError, Payload, TaskGraph, TaskId, TaskSpec};

#[derive(Debug, thiserror::Error)]
pub enum CodecError {
    #[error("msgpack: {0}")]
    Msgpack(#[from] DecodeError),
    #[error("message missing field {0:?}")]
    Missing(&'static str),
    #[error("field {0:?} has wrong type")]
    WrongType(&'static str),
    #[error("unknown op {0:?}")]
    UnknownOp(String),
    #[error("unknown payload kind {0:?}")]
    UnknownPayload(String),
    #[error("invalid graph: {0}")]
    Graph(#[from] GraphError),
}

// ---------- Value-tree helpers (cold path + reference codec) ----------

fn get<'a>(v: &'a Value, k: &'static str) -> Result<&'a Value, CodecError> {
    v.get(k).ok_or(CodecError::Missing(k))
}

fn get_str(v: &Value, k: &'static str) -> Result<String, CodecError> {
    get(v, k)?.as_str().map(str::to_string).ok_or(CodecError::WrongType(k))
}

fn get_u64(v: &Value, k: &'static str) -> Result<u64, CodecError> {
    get(v, k)?.as_u64().ok_or(CodecError::WrongType(k))
}

fn get_i64(v: &Value, k: &'static str) -> Result<i64, CodecError> {
    get(v, k)?.as_i64().ok_or(CodecError::WrongType(k))
}

fn get_bool(v: &Value, k: &'static str) -> Result<bool, CodecError> {
    get(v, k)?.as_bool().ok_or(CodecError::WrongType(k))
}

fn get_bin(v: &Value, k: &'static str) -> Result<Vec<u8>, CodecError> {
    get(v, k)?.as_bin().map(<[u8]>::to_vec).ok_or(CodecError::WrongType(k))
}

fn get_task(v: &Value, k: &'static str) -> Result<TaskId, CodecError> {
    Ok(TaskId(get_u64(v, k)? as u32))
}

fn get_run(v: &Value) -> Result<RunId, CodecError> {
    Ok(RunId(get_u64(v, "run")? as u32))
}

// ---------- payload ----------

fn payload_to_value(p: &Payload) -> Value {
    match p {
        Payload::NoOp => Value::map(vec![("kind", Value::str("noop"))]),
        Payload::BusyWait => Value::map(vec![("kind", Value::str("busywait"))]),
        Payload::MergeInputs => Value::map(vec![("kind", Value::str("merge"))]),
        Payload::HloReduce { rows, cols, seed } => Value::map(vec![
            ("kind", Value::str("hlo-reduce")),
            ("rows", Value::from(*rows)),
            ("cols", Value::from(*cols)),
            ("seed", Value::from(*seed)),
        ]),
        Payload::HloTranspose { n, seed } => Value::map(vec![
            ("kind", Value::str("hlo-transpose")),
            ("n", Value::from(*n)),
            ("seed", Value::from(*seed)),
        ]),
        Payload::HloHash { n_tokens, buckets, seed } => Value::map(vec![
            ("kind", Value::str("hlo-hash")),
            ("n_tokens", Value::from(*n_tokens)),
            ("buckets", Value::from(*buckets)),
            ("seed", Value::from(*seed)),
        ]),
        Payload::WordBag { n_docs, seed } => Value::map(vec![
            ("kind", Value::str("wordbag")),
            ("n_docs", Value::from(*n_docs)),
            ("seed", Value::from(*seed)),
        ]),
    }
}

fn payload_from_value(v: &Value) -> Result<Payload, CodecError> {
    let kind = get_str(v, "kind")?;
    Ok(match kind.as_str() {
        "noop" => Payload::NoOp,
        "busywait" => Payload::BusyWait,
        "merge" => Payload::MergeInputs,
        "hlo-reduce" => Payload::HloReduce {
            rows: get_u64(v, "rows")? as u32,
            cols: get_u64(v, "cols")? as u32,
            seed: get_u64(v, "seed")?,
        },
        "hlo-transpose" => {
            Payload::HloTranspose { n: get_u64(v, "n")? as u32, seed: get_u64(v, "seed")? }
        }
        "hlo-hash" => Payload::HloHash {
            n_tokens: get_u64(v, "n_tokens")? as u32,
            buckets: get_u64(v, "buckets")? as u32,
            seed: get_u64(v, "seed")?,
        },
        "wordbag" => {
            Payload::WordBag { n_docs: get_u64(v, "n_docs")? as u32, seed: get_u64(v, "seed")? }
        }
        other => return Err(CodecError::UnknownPayload(other.to_string())),
    })
}

/// Emit a payload spec with keys in sorted order (byte-identical to
/// [`payload_to_value`] + tree encode).
fn enc_payload(w: &mut Writer, p: &Payload) {
    match p {
        Payload::NoOp => {
            w.map_header(1);
            w.str("kind");
            w.str("noop");
        }
        Payload::BusyWait => {
            w.map_header(1);
            w.str("kind");
            w.str("busywait");
        }
        Payload::MergeInputs => {
            w.map_header(1);
            w.str("kind");
            w.str("merge");
        }
        Payload::HloReduce { rows, cols, seed } => {
            w.map_header(4);
            w.str("cols");
            w.uint(*cols as u64);
            w.str("kind");
            w.str("hlo-reduce");
            w.str("rows");
            w.uint(*rows as u64);
            w.str("seed");
            w.uint(*seed);
        }
        Payload::HloTranspose { n, seed } => {
            w.map_header(3);
            w.str("kind");
            w.str("hlo-transpose");
            w.str("n");
            w.uint(*n as u64);
            w.str("seed");
            w.uint(*seed);
        }
        Payload::HloHash { n_tokens, buckets, seed } => {
            w.map_header(4);
            w.str("buckets");
            w.uint(*buckets as u64);
            w.str("kind");
            w.str("hlo-hash");
            w.str("n_tokens");
            w.uint(*n_tokens as u64);
            w.str("seed");
            w.uint(*seed);
        }
        Payload::WordBag { n_docs, seed } => {
            w.map_header(3);
            w.str("kind");
            w.str("wordbag");
            w.str("n_docs");
            w.uint(*n_docs as u64);
            w.str("seed");
            w.uint(*seed);
        }
    }
}

/// Parse a payload spec from the stream (allocation-free: the kind is
/// matched as a borrowed `&str`).
fn dec_payload<'a>(r: &mut Reader<'a>) -> Result<Payload, CodecError> {
    let n = r.map_header().map_err(|e| wrong(e, "payload"))?;
    let mut kind: Option<&'a str> = None;
    let (mut rows, mut cols, mut seed) = (None, None, None);
    let (mut nn, mut n_tokens, mut buckets, mut n_docs) = (None, None, None, None);
    for _ in 0..n {
        match r.str()? {
            "kind" => kind = Some(r_str(r, "kind")?),
            "rows" => rows = Some(r_uint(r, "rows")? as u32),
            "cols" => cols = Some(r_uint(r, "cols")? as u32),
            "seed" => seed = Some(r_uint(r, "seed")?),
            "n" => nn = Some(r_uint(r, "n")? as u32),
            "n_tokens" => n_tokens = Some(r_uint(r, "n_tokens")? as u32),
            "buckets" => buckets = Some(r_uint(r, "buckets")? as u32),
            "n_docs" => n_docs = Some(r_uint(r, "n_docs")? as u32),
            _ => r.skip_value()?,
        }
    }
    Ok(match req(kind, "kind")? {
        "noop" => Payload::NoOp,
        "busywait" => Payload::BusyWait,
        "merge" => Payload::MergeInputs,
        "hlo-reduce" => Payload::HloReduce {
            rows: req(rows, "rows")?,
            cols: req(cols, "cols")?,
            seed: req(seed, "seed")?,
        },
        "hlo-transpose" => {
            Payload::HloTranspose { n: req(nn, "n")?, seed: req(seed, "seed")? }
        }
        "hlo-hash" => Payload::HloHash {
            n_tokens: req(n_tokens, "n_tokens")?,
            buckets: req(buckets, "buckets")?,
            seed: req(seed, "seed")?,
        },
        "wordbag" => {
            Payload::WordBag { n_docs: req(n_docs, "n_docs")?, seed: req(seed, "seed")? }
        }
        other => return Err(CodecError::UnknownPayload(other.to_string())),
    })
}

// ---------- graph ----------

/// One task spec as a wire map (shared by `submit-graph` and
/// `submit-extend`). `cores` is optional — omitted when 1, so
/// pre-resource frames stay byte-identical.
fn taskspec_to_value(t: &TaskSpec) -> Value {
    let mut fields = vec![
        ("key", Value::str(&t.key)),
        (
            "inputs",
            Value::Array(t.inputs.iter().map(|i| Value::from(i.0)).collect()),
        ),
        ("duration_us", Value::from(t.duration_us)),
        ("output_size", Value::from(t.output_size)),
        ("payload", payload_to_value(&t.payload)),
    ];
    if t.cores > 1 {
        fields.push(("cores", Value::from(t.cores)));
    }
    Value::map(fields)
}

/// Decode one wire task map; the dense id is assigned by the caller.
fn taskspec_from_value(tv: &Value, id: TaskId) -> Result<TaskSpec, CodecError> {
    let inputs_v = get(tv, "inputs")?.as_array().ok_or(CodecError::WrongType("inputs"))?;
    let inputs = inputs_v
        .iter()
        .map(|x| x.as_u64().map(|u| TaskId(u as u32)).ok_or(CodecError::WrongType("inputs")))
        .collect::<Result<Vec<_>, _>>()?;
    let cores = match tv.get("cores") {
        None => 1,
        Some(c) => c.as_u64().ok_or(CodecError::WrongType("cores"))? as u32,
    };
    Ok(TaskSpec {
        id,
        key: get_str(tv, "key")?,
        inputs,
        duration_us: get_u64(tv, "duration_us")?,
        output_size: get_u64(tv, "output_size")?,
        payload: payload_from_value(get(tv, "payload")?)?,
        cores,
    })
}

/// Encode a task graph as a msgpack value (used in `submit-graph`).
pub fn graph_to_value(g: &TaskGraph) -> Value {
    let tasks: Vec<Value> = g.tasks().iter().map(taskspec_to_value).collect();
    Value::map(vec![("name", Value::str(&g.name)), ("tasks", Value::Array(tasks))])
}

/// Decode a task graph (validates DAG invariants on arrival — a malicious
/// client cannot install a cyclic graph).
pub fn graph_from_value(v: &Value) -> Result<TaskGraph, CodecError> {
    let name = get_str(v, "name")?;
    let tasks_v = get(v, "tasks")?.as_array().ok_or(CodecError::WrongType("tasks"))?;
    let mut tasks = Vec::with_capacity(tasks_v.len());
    for (i, tv) in tasks_v.iter().enumerate() {
        tasks.push(taskspec_from_value(tv, TaskId(i as u32))?);
    }
    Ok(TaskGraph::new(name, tasks)?)
}

// ---------- streaming encode (production path) ----------

/// Encode a message to framed-ready bytes in a fresh buffer.
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    encode_msg_into(msg, &mut out);
    out
}

/// Encode a message, appending to `out`. The hot path: connections reuse
/// one output buffer, so a warm encode performs zero heap allocations.
pub fn encode_msg_into(msg: &Msg, out: &mut Vec<u8>) {
    match msg {
        // Cold path: the graph payload is a dynamic tree; build it as a
        // Value (the BTreeMap also takes care of key ordering).
        Msg::SubmitGraph { graph, scheduler, open } => {
            let mut fields: Vec<(&str, Value)> = vec![
                ("graph", graph_to_value(graph)),
                ("op", Value::str("submit-graph")),
            ];
            if *open {
                fields.push(("open", Value::Bool(true)));
            }
            if let Some(s) = scheduler {
                fields.push(("scheduler", Value::str(s)));
            }
            encode_into(&Value::map(fields), out);
        }
        // Cold path like submit-graph: a dynamic batch of task specs.
        // `base` (the dense id of the first new task) lets the decoder
        // reconstruct ids without carrying one per task.
        Msg::SubmitExtend { run, tasks, last } => {
            let base = tasks.first().map_or(0, |t| t.id.0);
            let fields: Vec<(&str, Value)> = vec![
                ("base", Value::from(base)),
                ("last", Value::Bool(*last)),
                ("op", Value::str("submit-extend")),
                ("run", Value::from(run.0)),
                ("tasks", Value::Array(tasks.iter().map(taskspec_to_value).collect())),
            ];
            encode_into(&Value::map(fields), out);
        }
        Msg::RegisterClient { name } => {
            let mut w = Writer::new(out);
            w.map_header(2);
            w.str("name");
            w.str(name);
            w.str("op");
            w.str("register-client");
        }
        Msg::RegisterWorker { name, ncores, node, data_addr } => {
            let mut w = Writer::new(out);
            w.map_header(5);
            w.str("data_addr");
            w.str(data_addr);
            w.str("name");
            w.str(name);
            w.str("ncores");
            w.uint(*ncores as u64);
            w.str("node");
            w.uint(*node as u64);
            w.str("op");
            w.str("register-worker");
        }
        Msg::Welcome { id } => {
            let mut w = Writer::new(out);
            w.map_header(2);
            w.str("id");
            w.uint(*id as u64);
            w.str("op");
            w.str("welcome");
        }
        Msg::GraphSubmitted { run, n_tasks } => {
            let mut w = Writer::new(out);
            w.map_header(3);
            w.str("n_tasks");
            w.uint(*n_tasks);
            w.str("op");
            w.str("graph-submitted");
            w.str("run");
            w.uint(run.0 as u64);
        }
        Msg::RunQueued { run, position } => {
            let mut w = Writer::new(out);
            w.map_header(3);
            w.str("op");
            w.str("run-queued");
            w.str("position");
            w.uint(*position);
            w.str("run");
            w.uint(run.0 as u64);
        }
        Msg::GraphDone { run, makespan_us, n_tasks } => {
            let mut w = Writer::new(out);
            w.map_header(4);
            w.str("makespan_us");
            w.uint(*makespan_us);
            w.str("n_tasks");
            w.uint(*n_tasks);
            w.str("op");
            w.str("graph-done");
            w.str("run");
            w.uint(run.0 as u64);
        }
        Msg::GraphFailed { run, reason } => {
            let mut w = Writer::new(out);
            w.map_header(3);
            w.str("op");
            w.str("graph-failed");
            w.str("reason");
            w.str(reason);
            w.str("run");
            w.uint(run.0 as u64);
        }
        Msg::ReleaseRun { run } => {
            let mut w = Writer::new(out);
            w.map_header(2);
            w.str("op");
            w.str("release-run");
            w.str("run");
            w.uint(run.0 as u64);
        }
        Msg::ComputeTask {
            run,
            task,
            key,
            payload,
            duration_us,
            output_size,
            inputs,
            priority,
            consumers,
            cores,
        } => {
            // Delegate to the borrowed encoder so the owned and borrowed
            // dispatch paths are byte-identical by construction.
            let parts = ComputeTaskParts {
                run: *run,
                task: *task,
                key,
                payload,
                duration_us: *duration_us,
                output_size: *output_size,
                priority: *priority,
                consumers: *consumers,
                cores: *cores,
            };
            encode_compute_task_into(
                &parts,
                inputs.iter().map(|l| {
                    let mut r = TaskInputRef::new(l.task, &l.addr, l.nbytes);
                    for a in &l.alts {
                        r.push_alt(a);
                    }
                    r
                }),
                out,
            );
        }
        Msg::PinData { run, task, consumers } => {
            let mut w = Writer::new(out);
            w.map_header(4);
            w.str("consumers");
            w.uint(*consumers as u64);
            w.str("op");
            w.str("pin-data");
            w.str("run");
            w.uint(run.0 as u64);
            w.str("task");
            w.uint(task.0 as u64);
        }
        Msg::TaskFinished(info) => {
            let mut w = Writer::new(out);
            w.map_header(5);
            w.str("duration_us");
            w.uint(info.duration_us);
            w.str("nbytes");
            w.uint(info.nbytes);
            w.str("op");
            w.str("task-finished");
            w.str("run");
            w.uint(info.run.0 as u64);
            w.str("task");
            w.uint(info.task.0 as u64);
        }
        Msg::TaskErred { run, task, error } => {
            let mut w = Writer::new(out);
            w.map_header(4);
            w.str("error");
            w.str(error);
            w.str("op");
            w.str("task-erred");
            w.str("run");
            w.uint(run.0 as u64);
            w.str("task");
            w.uint(task.0 as u64);
        }
        Msg::StealRequest { run, task } => enc_run_task(out, "steal-request", *run, *task),
        Msg::StealResponse { run, task, ok } => {
            let mut w = Writer::new(out);
            w.map_header(4);
            w.str("ok");
            w.boolean(*ok);
            w.str("op");
            w.str("steal-response");
            w.str("run");
            w.uint(run.0 as u64);
            w.str("task");
            w.uint(task.0 as u64);
        }
        Msg::CancelCompute { run, task } => enc_run_task(out, "cancel-compute", *run, *task),
        Msg::ReplicateData { run, task, addrs } => {
            let mut w = Writer::new(out);
            w.map_header(4);
            w.str("addrs");
            w.array_header(addrs.len());
            for a in addrs {
                w.str(a);
            }
            w.str("op");
            w.str("replicate-data");
            w.str("run");
            w.uint(run.0 as u64);
            w.str("task");
            w.uint(task.0 as u64);
        }
        Msg::PutData { run, task, data } => {
            enc_run_task_data(out, "put-data", *run, *task, data)
        }
        Msg::ReplicaAdded { run, task } => enc_run_task(out, "replica-added", *run, *task),
        Msg::ReplicaDropped { run, task } => {
            enc_run_task(out, "replica-dropped", *run, *task)
        }
        Msg::FetchData { run, task } => enc_run_task(out, "fetch-data", *run, *task),
        Msg::FetchDataMany { run, tasks } => encode_fetch_many_into(*run, tasks, out),
        Msg::FetchFromServer { run, task } => {
            enc_run_task(out, "fetch-from-server", *run, *task)
        }
        Msg::DataReply { run, task, data } => {
            enc_run_task_data(out, "data-reply", *run, *task, data)
        }
        Msg::DataToServer { run, task, data } => {
            enc_run_task_data(out, "data-to-server", *run, *task, data)
        }
        Msg::Shutdown | Msg::Heartbeat => {
            let mut w = Writer::new(out);
            w.map_header(1);
            w.str("op");
            w.str(msg.op());
        }
    }
}

/// The scalar fields of a `compute-task`, borrowed from wherever they
/// already live (the submitted graph, the worker registration table). The
/// allocation-free server dispatch path encodes straight from these plus a
/// borrowed input iterator — no owned [`Msg::ComputeTask`] is ever built.
#[derive(Debug, Clone, Copy)]
pub struct ComputeTaskParts<'a> {
    pub run: RunId,
    pub task: TaskId,
    pub key: &'a str,
    pub payload: &'a Payload,
    pub duration_us: u64,
    pub output_size: u64,
    pub priority: i64,
    /// Consumer count of the output (`0` = pinned; omitted on the wire so
    /// pre-replication frames stay byte-identical).
    pub consumers: u32,
    /// Core slots the task occupies (`1` = ordinary single-slot task;
    /// omitted on the wire so pre-resource frames stay byte-identical).
    pub cores: u32,
}

/// Encode a `compute-task` from borrowed parts, appending to `out`.
/// Byte-identical to encoding the equivalent owned [`Msg::ComputeTask`]
/// (the owned arm of [`encode_msg_into`] delegates here), so the wire
/// format is unchanged and the byte-identity property tests cover both.
pub fn encode_compute_task_into<'a, I>(parts: &ComputeTaskParts<'_>, inputs: I, out: &mut Vec<u8>)
where
    I: ExactSizeIterator<Item = TaskInputRef<'a>>,
{
    let mut w = Writer::new(out);
    // `consumers`, `cores` and per-input `alts` are optional fields
    // (precedent: the `scheduler` key on submit-graph): omitted when at
    // their defaults, so every pre-replication/pre-resource frame is
    // byte-unchanged. Key order stays sorted — "consumers" < "cores" <
    // "duration_us", "addr" < "alts" < "nbytes".
    let n_fields = 9 + (parts.consumers > 0) as usize + (parts.cores > 1) as usize;
    w.map_header(n_fields);
    if parts.consumers > 0 {
        w.str("consumers");
        w.uint(parts.consumers as u64);
    }
    if parts.cores > 1 {
        w.str("cores");
        w.uint(parts.cores as u64);
    }
    w.str("duration_us");
    w.uint(parts.duration_us);
    w.str("inputs");
    w.array_header(inputs.len());
    for l in inputs {
        let alts = l.alts();
        w.map_header(if alts.is_empty() { 3 } else { 4 });
        w.str("addr");
        w.str(l.addr);
        if !alts.is_empty() {
            w.str("alts");
            w.array_header(alts.len());
            for a in alts {
                w.str(a);
            }
        }
        w.str("nbytes");
        w.uint(l.nbytes);
        w.str("task");
        w.uint(l.task.0 as u64);
    }
    w.str("key");
    w.str(parts.key);
    w.str("op");
    w.str("compute-task");
    w.str("output_size");
    w.uint(parts.output_size);
    w.str("payload");
    enc_payload(&mut w, parts.payload);
    w.str("priority");
    w.int(parts.priority);
    w.str("run");
    w.uint(parts.run.0 as u64);
    w.str("task");
    w.uint(parts.task.0 as u64);
}

fn enc_run_task(out: &mut Vec<u8>, op: &str, run: RunId, task: TaskId) {
    let mut w = Writer::new(out);
    w.map_header(3);
    w.str("op");
    w.str(op);
    w.str("run");
    w.uint(run.0 as u64);
    w.str("task");
    w.uint(task.0 as u64);
}

fn enc_run_task_data(out: &mut Vec<u8>, op: &'static str, run: RunId, task: TaskId, data: &[u8]) {
    // Delegates to the split head/tail encoders so the zero-copy serve
    // path is byte-identical to the owned encoding by construction.
    let parts = DataFrameParts { op, run, task, data_len: data.len() };
    encode_data_frame_head(&parts, out);
    out.extend_from_slice(data);
    encode_data_frame_tail(&parts, out);
}

/// The scalar fields of a data-bearing frame (`data-reply` / `put-data` /
/// `data-to-server`), with the payload represented only by its length.
/// The data plane uses the split [`encode_data_frame_head`] /
/// [`encode_data_frame_tail`] encoders to frame a stored `Arc<Vec<u8>>`
/// without ever copying the payload into an encode buffer: the head ends
/// exactly at the bin payload boundary, the payload bytes are written (or
/// queued) straight from the store's buffer, and the tail carries the
/// remaining fields. Head + payload + tail is byte-identical to encoding
/// the equivalent owned [`Msg`] — the owned arms delegate here, so the
/// byte-identity suites cover both.
#[derive(Debug, Clone, Copy)]
pub struct DataFrameParts {
    /// Wire op — one of `"data-reply"`, `"put-data"`, `"data-to-server"`.
    pub op: &'static str,
    pub run: RunId,
    pub task: TaskId,
    /// Payload length in bytes; the bin header is emitted for exactly
    /// this many bytes, which the caller must supply between head and
    /// tail.
    pub data_len: usize,
}

/// Encode everything up to and including the bin header of the `data`
/// field (keys stay sorted: `data` sorts first). Appends to `out`.
pub fn encode_data_frame_head(parts: &DataFrameParts, out: &mut Vec<u8>) {
    let mut w = Writer::new(out);
    w.map_header(4);
    w.str("data");
    w.bin_header(parts.data_len);
}

/// Encode the fields after the `data` payload (`op`, `run`, `task`).
/// Appends to `out`.
pub fn encode_data_frame_tail(parts: &DataFrameParts, out: &mut Vec<u8>) {
    let mut w = Writer::new(out);
    w.str("op");
    w.str(parts.op);
    w.str("run");
    w.uint(parts.run.0 as u64);
    w.str("task");
    w.uint(parts.task.0 as u64);
}

/// Encode a `fetch-data-many` request from a borrowed task-id slice,
/// appending to `out`. Byte-identical to encoding the equivalent owned
/// [`Msg::FetchDataMany`] (the owned arm delegates here), so the gather
/// issue path never builds an owned message per peer batch.
pub fn encode_fetch_many_into(run: RunId, tasks: &[TaskId], out: &mut Vec<u8>) {
    let mut w = Writer::new(out);
    w.map_header(3);
    w.str("op");
    w.str("fetch-data-many");
    w.str("run");
    w.uint(run.0 as u64);
    w.str("tasks");
    w.array_header(tasks.len());
    for t in tasks {
        w.uint(t.0 as u64);
    }
}

// ---------- streaming decode (production path) ----------

/// Map a typed-read mismatch to the protocol-level error naming the field;
/// all other stream errors pass through as msgpack errors.
fn wrong(e: DecodeError, field: &'static str) -> CodecError {
    match e {
        DecodeError::Unexpected(..) => CodecError::WrongType(field),
        e => CodecError::Msgpack(e),
    }
}

fn r_uint(r: &mut Reader, f: &'static str) -> Result<u64, CodecError> {
    r.uint().map_err(|e| wrong(e, f))
}

fn r_int(r: &mut Reader, f: &'static str) -> Result<i64, CodecError> {
    r.int().map_err(|e| wrong(e, f))
}

fn r_bool(r: &mut Reader, f: &'static str) -> Result<bool, CodecError> {
    r.boolean().map_err(|e| wrong(e, f))
}

fn r_str<'a>(r: &mut Reader<'a>, f: &'static str) -> Result<&'a str, CodecError> {
    r.str().map_err(|e| wrong(e, f))
}

fn r_bin<'a>(r: &mut Reader<'a>, f: &'static str) -> Result<&'a [u8], CodecError> {
    r.bin().map_err(|e| wrong(e, f))
}

fn req<T>(v: Option<T>, f: &'static str) -> Result<T, CodecError> {
    v.ok_or(CodecError::Missing(f))
}

/// Reject bytes left over after the message map — framing guarantees one
/// message per frame, so trailing bytes mean corruption.
fn finish(r: &Reader, bytes: &[u8]) -> Result<(), CodecError> {
    if r.pos() != bytes.len() {
        return Err(CodecError::Msgpack(DecodeError::Trailing(bytes.len() - r.pos())));
    }
    Ok(())
}

/// First pass: find the `"op"` discriminant without materializing anything.
///
/// Deliberate two-pass design: decoders accept fields in any order (forward
/// compat), so dispatch needs the op before field extraction. The extra
/// walk skips values without materializing them and the hot-path maps are
/// a handful of keys, so the cost is a few nanoseconds — still >2x faster
/// end to end than the `Value`-tree decode it replaces.
fn find_op(bytes: &[u8]) -> Result<&str, CodecError> {
    let mut r = Reader::new(bytes);
    let n = r.map_header()?;
    for _ in 0..n {
        let key = r.str()?;
        if key == "op" {
            return r_str(&mut r, "op");
        }
        r.skip_value()?;
    }
    Err(CodecError::Missing("op"))
}

/// Peek a frame's `"op"` discriminant without materializing anything.
/// Receivers that special-case one op (the worker routes `compute-task`
/// through the borrowed [`ComputeTaskView`] instead of the owned decode)
/// branch on this before choosing a decoder.
pub fn peek_op(bytes: &[u8]) -> Result<&str, CodecError> {
    find_op(bytes)
}

/// Decode one message from bytes (streaming: field names are matched as
/// borrowed `&str`s, never allocated).
pub fn decode_msg(bytes: &[u8]) -> Result<Msg, CodecError> {
    match find_op(bytes)? {
        // Cold path: dynamic payloads go through the Value tree.
        "submit-graph" | "submit-extend" | "register-client" | "register-worker" => {
            decode_msg_value(bytes)
        }
        "welcome" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let mut id = None;
            for _ in 0..n {
                match r.str()? {
                    "id" => id = Some(r_uint(&mut r, "id")? as u32),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::Welcome { id: req(id, "id")? })
        }
        "graph-submitted" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut n_tasks) = (None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "n_tasks" => n_tasks = Some(r_uint(&mut r, "n_tasks")?),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::GraphSubmitted {
                run: RunId(req(run, "run")?),
                n_tasks: req(n_tasks, "n_tasks")?,
            })
        }
        "run-queued" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut position) = (None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "position" => position = Some(r_uint(&mut r, "position")?),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::RunQueued {
                run: RunId(req(run, "run")?),
                position: req(position, "position")?,
            })
        }
        "graph-done" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut makespan_us, mut n_tasks) = (None, None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "makespan_us" => makespan_us = Some(r_uint(&mut r, "makespan_us")?),
                    "n_tasks" => n_tasks = Some(r_uint(&mut r, "n_tasks")?),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::GraphDone {
                run: RunId(req(run, "run")?),
                makespan_us: req(makespan_us, "makespan_us")?,
                n_tasks: req(n_tasks, "n_tasks")?,
            })
        }
        "graph-failed" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut reason) = (None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "reason" => reason = Some(r_str(&mut r, "reason")?.to_string()),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::GraphFailed {
                run: RunId(req(run, "run")?),
                reason: req(reason, "reason")?,
            })
        }
        "release-run" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let mut run = None;
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::ReleaseRun { run: RunId(req(run, "run")?) })
        }
        "compute-task" => dec_compute_task(bytes),
        "pin-data" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut task, mut consumers) = (None, None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "task" => task = Some(r_uint(&mut r, "task")? as u32),
                    "consumers" => consumers = Some(r_uint(&mut r, "consumers")? as u32),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::PinData {
                run: RunId(req(run, "run")?),
                task: TaskId(req(task, "task")?),
                consumers: req(consumers, "consumers")?,
            })
        }
        "task-finished" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut task, mut nbytes, mut duration_us) = (None, None, None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "task" => task = Some(r_uint(&mut r, "task")? as u32),
                    "nbytes" => nbytes = Some(r_uint(&mut r, "nbytes")?),
                    "duration_us" => duration_us = Some(r_uint(&mut r, "duration_us")?),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::TaskFinished(TaskFinishedInfo {
                run: RunId(req(run, "run")?),
                task: TaskId(req(task, "task")?),
                nbytes: req(nbytes, "nbytes")?,
                duration_us: req(duration_us, "duration_us")?,
            }))
        }
        "task-erred" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut task, mut error) = (None, None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "task" => task = Some(r_uint(&mut r, "task")? as u32),
                    "error" => error = Some(r_str(&mut r, "error")?.to_string()),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::TaskErred {
                run: RunId(req(run, "run")?),
                task: TaskId(req(task, "task")?),
                error: req(error, "error")?,
            })
        }
        "steal-request" => {
            let (run, task) = dec_run_task(bytes)?;
            Ok(Msg::StealRequest { run, task })
        }
        "steal-response" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut task, mut ok) = (None, None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "task" => task = Some(r_uint(&mut r, "task")? as u32),
                    "ok" => ok = Some(r_bool(&mut r, "ok")?),
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::StealResponse {
                run: RunId(req(run, "run")?),
                task: TaskId(req(task, "task")?),
                ok: req(ok, "ok")?,
            })
        }
        "cancel-compute" => {
            let (run, task) = dec_run_task(bytes)?;
            Ok(Msg::CancelCompute { run, task })
        }
        "replicate-data" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut task, mut addrs) = (None, None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "task" => task = Some(r_uint(&mut r, "task")? as u32),
                    "addrs" => {
                        let k = r.array_header().map_err(|e| wrong(e, "addrs"))?;
                        let mut v = Vec::with_capacity(k.min(64));
                        for _ in 0..k {
                            v.push(r_str(&mut r, "addrs")?.to_string());
                        }
                        addrs = Some(v);
                    }
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::ReplicateData {
                run: RunId(req(run, "run")?),
                task: TaskId(req(task, "task")?),
                addrs: req(addrs, "addrs")?,
            })
        }
        "put-data" => {
            let (run, task, data) = dec_run_task_data(bytes)?;
            Ok(Msg::PutData { run, task, data })
        }
        "replica-added" => {
            let (run, task) = dec_run_task(bytes)?;
            Ok(Msg::ReplicaAdded { run, task })
        }
        "replica-dropped" => {
            let (run, task) = dec_run_task(bytes)?;
            Ok(Msg::ReplicaDropped { run, task })
        }
        "fetch-data" => {
            let (run, task) = dec_run_task(bytes)?;
            Ok(Msg::FetchData { run, task })
        }
        "fetch-data-many" => {
            let mut r = Reader::new(bytes);
            let n = r.map_header()?;
            let (mut run, mut tasks) = (None, None);
            for _ in 0..n {
                match r.str()? {
                    "run" => run = Some(r_uint(&mut r, "run")? as u32),
                    "tasks" => {
                        let k = r.array_header().map_err(|e| wrong(e, "tasks"))?;
                        let mut v = Vec::with_capacity(k.min(1024));
                        for _ in 0..k {
                            v.push(TaskId(r_uint(&mut r, "tasks")? as u32));
                        }
                        tasks = Some(v);
                    }
                    _ => r.skip_value()?,
                }
            }
            finish(&r, bytes)?;
            Ok(Msg::FetchDataMany {
                run: RunId(req(run, "run")?),
                tasks: req(tasks, "tasks")?,
            })
        }
        "fetch-from-server" => {
            let (run, task) = dec_run_task(bytes)?;
            Ok(Msg::FetchFromServer { run, task })
        }
        "data-reply" => {
            let (run, task, data) = dec_run_task_data(bytes)?;
            Ok(Msg::DataReply { run, task, data })
        }
        "data-to-server" => {
            let (run, task, data) = dec_run_task_data(bytes)?;
            Ok(Msg::DataToServer { run, task, data })
        }
        "shutdown" => {
            dec_op_only(bytes)?;
            Ok(Msg::Shutdown)
        }
        "heartbeat" => {
            dec_op_only(bytes)?;
            Ok(Msg::Heartbeat)
        }
        other => Err(CodecError::UnknownOp(other.to_string())),
    }
}

fn dec_run_task(bytes: &[u8]) -> Result<(RunId, TaskId), CodecError> {
    let mut r = Reader::new(bytes);
    let n = r.map_header()?;
    let (mut run, mut task) = (None, None);
    for _ in 0..n {
        match r.str()? {
            "run" => run = Some(r_uint(&mut r, "run")? as u32),
            "task" => task = Some(r_uint(&mut r, "task")? as u32),
            _ => r.skip_value()?,
        }
    }
    finish(&r, bytes)?;
    Ok((RunId(req(run, "run")?), TaskId(req(task, "task")?)))
}

fn dec_run_task_data(bytes: &[u8]) -> Result<(RunId, TaskId, Vec<u8>), CodecError> {
    let mut r = Reader::new(bytes);
    let n = r.map_header()?;
    let (mut run, mut task, mut data) = (None, None, None);
    for _ in 0..n {
        match r.str()? {
            "run" => run = Some(r_uint(&mut r, "run")? as u32),
            "task" => task = Some(r_uint(&mut r, "task")? as u32),
            "data" => data = Some(r_bin(&mut r, "data")?.to_vec()),
            _ => r.skip_value()?,
        }
    }
    finish(&r, bytes)?;
    Ok((
        RunId(req(run, "run")?),
        TaskId(req(task, "task")?),
        req(data, "data")?,
    ))
}

fn dec_op_only(bytes: &[u8]) -> Result<(), CodecError> {
    let mut r = Reader::new(bytes);
    let n = r.map_header()?;
    for _ in 0..n {
        r.str()?;
        r.skip_value()?;
    }
    finish(&r, bytes)
}

fn dec_compute_task(bytes: &[u8]) -> Result<Msg, CodecError> {
    let mut r = Reader::new(bytes);
    let n = r.map_header()?;
    let (mut run, mut task, mut key, mut payload) = (None, None, None, None);
    let (mut duration_us, mut output_size, mut inputs, mut priority) = (None, None, None, None);
    let mut consumers = 0u32;
    let mut cores = 1u32;
    for _ in 0..n {
        match r.str()? {
            "run" => run = Some(r_uint(&mut r, "run")? as u32),
            "task" => task = Some(r_uint(&mut r, "task")? as u32),
            "key" => key = Some(r_str(&mut r, "key")?.to_string()),
            "payload" => payload = Some(dec_payload(&mut r)?),
            "duration_us" => duration_us = Some(r_uint(&mut r, "duration_us")?),
            "output_size" => output_size = Some(r_uint(&mut r, "output_size")?),
            "priority" => priority = Some(r_int(&mut r, "priority")?),
            "consumers" => consumers = r_uint(&mut r, "consumers")? as u32,
            "cores" => cores = r_uint(&mut r, "cores")? as u32,
            "inputs" => inputs = Some(dec_inputs(&mut r)?),
            _ => r.skip_value()?,
        }
    }
    finish(&r, bytes)?;
    Ok(Msg::ComputeTask {
        run: RunId(req(run, "run")?),
        task: TaskId(req(task, "task")?),
        key: req(key, "key")?,
        payload: req(payload, "payload")?,
        duration_us: req(duration_us, "duration_us")?,
        output_size: req(output_size, "output_size")?,
        inputs: req(inputs, "inputs")?,
        priority: req(priority, "priority")?,
        consumers,
        cores,
    })
}

fn dec_inputs(r: &mut Reader) -> Result<Vec<TaskInputLoc>, CodecError> {
    let n = r.array_header().map_err(|e| wrong(e, "inputs"))?;
    // Cap the speculative reservation: a lying header cannot force a huge
    // allocation (parsing will hit Eof long before).
    let mut v = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let m = r.map_header().map_err(|e| wrong(e, "inputs"))?;
        let (mut task, mut addr, mut nbytes) = (None, None, None);
        let mut alts = Vec::new();
        for _ in 0..m {
            match r.str()? {
                "task" => task = Some(r_uint(r, "task")? as u32),
                "addr" => addr = Some(r_str(r, "addr")?.to_string()),
                "nbytes" => nbytes = Some(r_uint(r, "nbytes")?),
                "alts" => {
                    let k = r.array_header().map_err(|e| wrong(e, "alts"))?;
                    for i in 0..k {
                        let a = r_str(r, "alts")?;
                        // Truncate (don't reject) past the protocol cap so
                        // the owned and borrowed decodes agree on the same
                        // first MAX_ALT_ADDRS entries.
                        if i < MAX_ALT_ADDRS {
                            alts.push(a.to_string());
                        }
                    }
                }
                _ => r.skip_value()?,
            }
        }
        v.push(TaskInputLoc {
            task: TaskId(req(task, "task")?),
            addr: req(addr, "addr")?,
            alts,
            nbytes: req(nbytes, "nbytes")?,
        });
    }
    Ok(v)
}

// ---------- borrowed compute-task view ----------

/// Fully borrowed, allocation-free decode of a `compute-task` frame: the
/// key is a `&str` into the frame, the inputs stay raw until iterated.
/// This is the zero-allocation form of the assignment message the
/// counting-allocator bench verifies; executors that must own the task
/// anyway use [`decode_msg`], which allocates only the task's real fields.
pub struct ComputeTaskView<'a> {
    pub run: RunId,
    pub task: TaskId,
    pub key: &'a str,
    pub payload: Payload,
    pub duration_us: u64,
    pub output_size: u64,
    pub priority: i64,
    /// Output consumer count (`0` when absent: pin in the store).
    pub consumers: u32,
    /// Core slots the task occupies (`1` when absent).
    pub cores: u32,
    n_inputs: usize,
    inputs_raw: &'a [u8],
}

/// One input location borrowed from a `compute-task` frame (or from the
/// server's `who_has` tables on the dispatch path). Alternate replica
/// addresses live in a fixed inline array — [`MAX_ALT_ADDRS`] caps the
/// wire field — so the borrowed form never allocates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskInputRef<'a> {
    pub task: TaskId,
    pub addr: &'a str,
    pub nbytes: u64,
    alts: [&'a str; MAX_ALT_ADDRS],
    n_alts: u8,
}

impl<'a> TaskInputRef<'a> {
    pub fn new(task: TaskId, addr: &'a str, nbytes: u64) -> TaskInputRef<'a> {
        TaskInputRef { task, addr, nbytes, alts: [""; MAX_ALT_ADDRS], n_alts: 0 }
    }

    /// Append an alternate replica address; silently drops past the
    /// protocol cap (producers never exceed it — the server emits at most
    /// `ReplicaSet::INLINE` = [`MAX_ALT_ADDRS`] alternates).
    pub fn push_alt(&mut self, addr: &'a str) {
        if (self.n_alts as usize) < MAX_ALT_ADDRS {
            self.alts[self.n_alts as usize] = addr;
            self.n_alts += 1;
        }
    }

    /// The alternate replica addresses (possibly empty).
    pub fn alts(&self) -> &[&'a str] {
        &self.alts[..self.n_alts as usize]
    }
}

impl<'a> ComputeTaskView<'a> {
    pub fn decode(bytes: &'a [u8]) -> Result<ComputeTaskView<'a>, CodecError> {
        let mut r = Reader::new(bytes);
        let n = r.map_header()?;
        let (mut run, mut task, mut key, mut payload) = (None, None, None, None);
        let (mut duration_us, mut output_size, mut priority) = (None, None, None);
        let mut consumers = 0u32;
        let mut cores = 1u32;
        let mut inputs: Option<(usize, &'a [u8])> = None;
        let mut op: Option<&'a str> = None;
        for _ in 0..n {
            match r.str()? {
                "op" => op = Some(r_str(&mut r, "op")?),
                "run" => run = Some(r_uint(&mut r, "run")? as u32),
                "task" => task = Some(r_uint(&mut r, "task")? as u32),
                "key" => key = Some(r_str(&mut r, "key")?),
                "payload" => payload = Some(dec_payload(&mut r)?),
                "duration_us" => duration_us = Some(r_uint(&mut r, "duration_us")?),
                "output_size" => output_size = Some(r_uint(&mut r, "output_size")?),
                "priority" => priority = Some(r_int(&mut r, "priority")?),
                "consumers" => consumers = r_uint(&mut r, "consumers")? as u32,
                "cores" => cores = r_uint(&mut r, "cores")? as u32,
                "inputs" => {
                    let cnt = r.array_header().map_err(|e| wrong(e, "inputs"))?;
                    let start = r.pos();
                    for _ in 0..cnt {
                        r.skip_value()?;
                    }
                    inputs = Some((cnt, &bytes[start..r.pos()]));
                }
                _ => r.skip_value()?,
            }
        }
        finish(&r, bytes)?;
        match req(op, "op")? {
            "compute-task" => {}
            other => return Err(CodecError::UnknownOp(other.to_string())),
        }
        let (n_inputs, inputs_raw) = req(inputs, "inputs")?;
        Ok(ComputeTaskView {
            run: RunId(req(run, "run")?),
            task: TaskId(req(task, "task")?),
            key: req(key, "key")?,
            payload: req(payload, "payload")?,
            duration_us: req(duration_us, "duration_us")?,
            output_size: req(output_size, "output_size")?,
            priority: req(priority, "priority")?,
            consumers,
            cores,
            n_inputs,
            inputs_raw,
        })
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Lazily parse the input locations (no allocation per item).
    pub fn inputs(&self) -> InputsIter<'a> {
        InputsIter { r: Reader::new(self.inputs_raw), remaining: self.n_inputs }
    }
}

/// Iterator over a [`ComputeTaskView`]'s borrowed input locations.
pub struct InputsIter<'a> {
    r: Reader<'a>,
    remaining: usize,
}

impl<'a> Iterator for InputsIter<'a> {
    type Item = Result<TaskInputRef<'a>, CodecError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        Some(dec_input_ref(&mut self.r))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for InputsIter<'_> {
    fn len(&self) -> usize {
        self.remaining
    }
}

fn dec_input_ref<'a>(r: &mut Reader<'a>) -> Result<TaskInputRef<'a>, CodecError> {
    let m = r.map_header().map_err(|e| wrong(e, "inputs"))?;
    let (mut task, mut addr, mut nbytes) = (None, None, None);
    let mut alts: [&'a str; MAX_ALT_ADDRS] = [""; MAX_ALT_ADDRS];
    let mut n_alts = 0u8;
    for _ in 0..m {
        match r.str()? {
            "task" => task = Some(r_uint(r, "task")? as u32),
            "addr" => addr = Some(r_str(r, "addr")?),
            "nbytes" => nbytes = Some(r_uint(r, "nbytes")?),
            "alts" => {
                let k = r.array_header().map_err(|e| wrong(e, "alts"))?;
                for i in 0..k {
                    let a = r_str(r, "alts")?;
                    // Same truncation rule as the owned decode.
                    if i < MAX_ALT_ADDRS {
                        alts[i] = a;
                        n_alts = (i + 1) as u8;
                    }
                }
            }
            _ => r.skip_value()?,
        }
    }
    let mut out = TaskInputRef::new(
        TaskId(req(task, "task")?),
        req(addr, "addr")?,
        req(nbytes, "nbytes")?,
    );
    for a in alts[..n_alts as usize].iter().copied() {
        out.push_alt(a);
    }
    Ok(out)
}

// ---------- Value-tree reference codec ----------

/// Encode a message through the owned [`Value`] tree. Reference codec: kept
/// for the byte-identity property tests against the streaming encoder (and
/// as the fallback if a future message outgrows static structure).
pub fn encode_msg_value(msg: &Msg) -> Vec<u8> {
    let mut fields: Vec<(&str, Value)> = vec![("op", Value::str(msg.op()))];
    match msg {
        Msg::RegisterClient { name } => fields.push(("name", Value::str(name))),
        Msg::RegisterWorker { name, ncores, node, data_addr } => {
            fields.push(("name", Value::str(name)));
            fields.push(("ncores", Value::from(*ncores)));
            fields.push(("node", Value::from(*node)));
            fields.push(("data_addr", Value::str(data_addr)));
        }
        Msg::Welcome { id } => fields.push(("id", Value::from(*id))),
        Msg::SubmitGraph { graph, scheduler, open } => {
            fields.push(("graph", graph_to_value(graph)));
            if *open {
                fields.push(("open", Value::Bool(true)));
            }
            if let Some(s) = scheduler {
                fields.push(("scheduler", Value::str(s)));
            }
        }
        Msg::SubmitExtend { run, tasks, last } => {
            fields.push(("base", Value::from(tasks.first().map_or(0, |t| t.id.0))));
            fields.push(("last", Value::Bool(*last)));
            fields.push(("run", Value::from(run.0)));
            fields.push((
                "tasks",
                Value::Array(tasks.iter().map(taskspec_to_value).collect()),
            ));
        }
        Msg::GraphSubmitted { run, n_tasks } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("n_tasks", Value::from(*n_tasks)));
        }
        Msg::RunQueued { run, position } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("position", Value::from(*position)));
        }
        Msg::GraphDone { run, makespan_us, n_tasks } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("makespan_us", Value::from(*makespan_us)));
            fields.push(("n_tasks", Value::from(*n_tasks)));
        }
        Msg::GraphFailed { run, reason } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("reason", Value::str(reason)));
        }
        Msg::ReleaseRun { run } => fields.push(("run", Value::from(run.0))),
        Msg::ComputeTask {
            run,
            task,
            key,
            payload,
            duration_us,
            output_size,
            inputs,
            priority,
            consumers,
            cores,
        } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("key", Value::str(key)));
            fields.push(("payload", payload_to_value(payload)));
            fields.push(("duration_us", Value::from(*duration_us)));
            fields.push(("output_size", Value::from(*output_size)));
            if *consumers > 0 {
                fields.push(("consumers", Value::from(*consumers)));
            }
            if *cores > 1 {
                fields.push(("cores", Value::from(*cores)));
            }
            fields.push((
                "inputs",
                Value::Array(
                    inputs
                        .iter()
                        .map(|l| {
                            let mut f = vec![
                                ("task", Value::from(l.task.0)),
                                ("addr", Value::str(&l.addr)),
                                ("nbytes", Value::from(l.nbytes)),
                            ];
                            if !l.alts.is_empty() {
                                f.push((
                                    "alts",
                                    Value::Array(
                                        l.alts.iter().map(|a| Value::str(a)).collect(),
                                    ),
                                ));
                            }
                            Value::map(f)
                        })
                        .collect(),
                ),
            ));
            fields.push(("priority", Value::Int(*priority)));
        }
        Msg::PinData { run, task, consumers } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("consumers", Value::from(*consumers)));
        }
        Msg::TaskFinished(info) => {
            fields.push(("run", Value::from(info.run.0)));
            fields.push(("task", Value::from(info.task.0)));
            fields.push(("nbytes", Value::from(info.nbytes)));
            fields.push(("duration_us", Value::from(info.duration_us)));
        }
        Msg::TaskErred { run, task, error } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("error", Value::str(error)));
        }
        Msg::StealRequest { run, task }
        | Msg::CancelCompute { run, task }
        | Msg::ReplicaAdded { run, task }
        | Msg::ReplicaDropped { run, task } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
        }
        Msg::ReplicateData { run, task, addrs } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push((
                "addrs",
                Value::Array(addrs.iter().map(|a| Value::str(a)).collect()),
            ));
        }
        Msg::PutData { run, task, data } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("data", Value::Bin(data.clone())));
        }
        Msg::StealResponse { run, task, ok } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("ok", Value::Bool(*ok)));
        }
        Msg::FetchData { run, task } | Msg::FetchFromServer { run, task } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
        }
        Msg::FetchDataMany { run, tasks } => {
            fields.push(("run", Value::from(run.0)));
            fields.push((
                "tasks",
                Value::Array(tasks.iter().map(|t| Value::from(t.0)).collect()),
            ));
        }
        Msg::DataReply { run, task, data } | Msg::DataToServer { run, task, data } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("data", Value::Bin(data.clone())));
        }
        Msg::Shutdown | Msg::Heartbeat => {}
    }
    encode(&Value::map(fields))
}

/// Decode one message through the owned [`Value`] tree (cold path for
/// `submit-graph` / registration; reference codec in tests).
pub fn decode_msg_value(bytes: &[u8]) -> Result<Msg, CodecError> {
    let v = decode(bytes)?;
    let op = get_str(&v, "op")?;
    Ok(match op.as_str() {
        "register-client" => Msg::RegisterClient { name: get_str(&v, "name")? },
        "register-worker" => Msg::RegisterWorker {
            name: get_str(&v, "name")?,
            ncores: get_u64(&v, "ncores")? as u32,
            node: get_u64(&v, "node")? as u32,
            data_addr: get_str(&v, "data_addr")?,
        },
        "welcome" => Msg::Welcome { id: get_u64(&v, "id")? as u32 },
        "submit-graph" => {
            let scheduler = match v.get("scheduler") {
                None => None,
                Some(s) => Some(
                    s.as_str()
                        .ok_or(CodecError::WrongType("scheduler"))?
                        .to_string(),
                ),
            };
            let open = match v.get("open") {
                None => false,
                Some(o) => o.as_bool().ok_or(CodecError::WrongType("open"))?,
            };
            Msg::SubmitGraph { graph: graph_from_value(get(&v, "graph")?)?, scheduler, open }
        }
        "submit-extend" => {
            let base = get_u64(&v, "base")? as u32;
            let tasks_v = get(&v, "tasks")?.as_array().ok_or(CodecError::WrongType("tasks"))?;
            let mut tasks = Vec::with_capacity(tasks_v.len());
            for (i, tv) in tasks_v.iter().enumerate() {
                tasks.push(taskspec_from_value(tv, TaskId(base + i as u32))?);
            }
            Msg::SubmitExtend { run: get_run(&v)?, tasks, last: get_bool(&v, "last")? }
        }
        "graph-submitted" => {
            Msg::GraphSubmitted { run: get_run(&v)?, n_tasks: get_u64(&v, "n_tasks")? }
        }
        "run-queued" => {
            Msg::RunQueued { run: get_run(&v)?, position: get_u64(&v, "position")? }
        }
        "graph-done" => Msg::GraphDone {
            run: get_run(&v)?,
            makespan_us: get_u64(&v, "makespan_us")?,
            n_tasks: get_u64(&v, "n_tasks")?,
        },
        "graph-failed" => {
            Msg::GraphFailed { run: get_run(&v)?, reason: get_str(&v, "reason")? }
        }
        "release-run" => Msg::ReleaseRun { run: get_run(&v)? },
        "compute-task" => {
            let inputs_v =
                get(&v, "inputs")?.as_array().ok_or(CodecError::WrongType("inputs"))?;
            let inputs = inputs_v
                .iter()
                .map(|l| {
                    let alts = match l.get("alts") {
                        None => Vec::new(),
                        Some(a) => a
                            .as_array()
                            .ok_or(CodecError::WrongType("alts"))?
                            .iter()
                            .take(MAX_ALT_ADDRS)
                            .map(|s| {
                                s.as_str()
                                    .map(str::to_string)
                                    .ok_or(CodecError::WrongType("alts"))
                            })
                            .collect::<Result<Vec<_>, _>>()?,
                    };
                    Ok(TaskInputLoc {
                        task: get_task(l, "task")?,
                        addr: get_str(l, "addr")?,
                        alts,
                        nbytes: get_u64(l, "nbytes")?,
                    })
                })
                .collect::<Result<Vec<_>, CodecError>>()?;
            let consumers = match v.get("consumers") {
                None => 0,
                Some(c) => c.as_u64().ok_or(CodecError::WrongType("consumers"))? as u32,
            };
            let cores = match v.get("cores") {
                None => 1,
                Some(c) => c.as_u64().ok_or(CodecError::WrongType("cores"))? as u32,
            };
            Msg::ComputeTask {
                run: get_run(&v)?,
                task: get_task(&v, "task")?,
                key: get_str(&v, "key")?,
                payload: payload_from_value(get(&v, "payload")?)?,
                duration_us: get_u64(&v, "duration_us")?,
                output_size: get_u64(&v, "output_size")?,
                inputs,
                priority: get_i64(&v, "priority")?,
                consumers,
                cores,
            }
        }
        "pin-data" => Msg::PinData {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            consumers: get_u64(&v, "consumers")? as u32,
        },
        "task-finished" => Msg::TaskFinished(TaskFinishedInfo {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            nbytes: get_u64(&v, "nbytes")?,
            duration_us: get_u64(&v, "duration_us")?,
        }),
        "task-erred" => Msg::TaskErred {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            error: get_str(&v, "error")?,
        },
        "steal-request" => Msg::StealRequest { run: get_run(&v)?, task: get_task(&v, "task")? },
        "cancel-compute" => {
            Msg::CancelCompute { run: get_run(&v)?, task: get_task(&v, "task")? }
        }
        "replicate-data" => {
            let addrs = get(&v, "addrs")?
                .as_array()
                .ok_or(CodecError::WrongType("addrs"))?
                .iter()
                .map(|a| {
                    a.as_str().map(str::to_string).ok_or(CodecError::WrongType("addrs"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            Msg::ReplicateData { run: get_run(&v)?, task: get_task(&v, "task")?, addrs }
        }
        "put-data" => Msg::PutData {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            data: get_bin(&v, "data")?,
        },
        "replica-added" => {
            Msg::ReplicaAdded { run: get_run(&v)?, task: get_task(&v, "task")? }
        }
        "replica-dropped" => {
            Msg::ReplicaDropped { run: get_run(&v)?, task: get_task(&v, "task")? }
        }
        "steal-response" => Msg::StealResponse {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            ok: get_bool(&v, "ok")?,
        },
        "fetch-data" => Msg::FetchData { run: get_run(&v)?, task: get_task(&v, "task")? },
        "fetch-data-many" => {
            let tasks = get(&v, "tasks")?
                .as_array()
                .ok_or(CodecError::WrongType("tasks"))?
                .iter()
                .map(|t| {
                    t.as_u64().map(|u| TaskId(u as u32)).ok_or(CodecError::WrongType("tasks"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            Msg::FetchDataMany { run: get_run(&v)?, tasks }
        }
        "data-reply" => Msg::DataReply {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            data: get_bin(&v, "data")?,
        },
        "fetch-from-server" => {
            Msg::FetchFromServer { run: get_run(&v)?, task: get_task(&v, "task")? }
        }
        "data-to-server" => Msg::DataToServer {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            data: get_bin(&v, "data")?,
        },
        "shutdown" => Msg::Shutdown,
        "heartbeat" => Msg::Heartbeat,
        other => return Err(CodecError::UnknownOp(other.to_string())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen;

    /// Round-trip through BOTH codecs and assert they agree byte-for-byte.
    fn rt(m: Msg) {
        let bytes = encode_msg(&m);
        assert_eq!(
            bytes,
            encode_msg_value(&m),
            "streaming and Value-tree encoders must be byte-identical for {m:?}"
        );
        let back = decode_msg(&bytes).unwrap_or_else(|e| panic!("{m:?}: {e}"));
        assert_eq!(back, m);
        let back_value = decode_msg_value(&bytes).unwrap_or_else(|e| panic!("{m:?}: {e}"));
        assert_eq!(back_value, m);
    }

    fn all_test_messages() -> Vec<Msg> {
        vec![
            Msg::RegisterClient { name: "client-1".into() },
            Msg::RegisterWorker {
                name: "w3".into(),
                ncores: 1,
                node: 2,
                data_addr: "127.0.0.1:9123".into(),
            },
            Msg::Welcome { id: 17 },
            Msg::GraphSubmitted { run: RunId(3), n_tasks: 10_001 },
            Msg::RunQueued { run: RunId(9), position: 2 },
            Msg::GraphDone { run: RunId(3), makespan_us: 123_456, n_tasks: 10_001 },
            Msg::GraphFailed { run: RunId(7), reason: "worker died".into() },
            Msg::ReleaseRun { run: RunId(7) },
            Msg::ComputeTask {
                run: RunId(2),
                task: TaskId(42),
                key: "merge-42".into(),
                payload: Payload::HloReduce { rows: 64, cols: 128, seed: 7 },
                duration_us: 1000,
                output_size: 2048,
                inputs: vec![
                    TaskInputLoc {
                        task: TaskId(1),
                        addr: "10.0.0.1:9000".into(),
                        alts: vec![],
                        nbytes: 500,
                    },
                    TaskInputLoc {
                        task: TaskId(2),
                        addr: String::new(),
                        alts: vec![],
                        nbytes: 10,
                    },
                ],
                priority: -5,
                consumers: 0,
                cores: 1,
            },
            // Replication-era compute-task: consumer refcount plus replica
            // alternates on one input (and none on the other — the
            // optional field must be per-input).
            Msg::ComputeTask {
                run: RunId(2),
                task: TaskId(43),
                key: "merge-43".into(),
                payload: Payload::MergeInputs,
                duration_us: 50,
                output_size: 64,
                inputs: vec![
                    TaskInputLoc {
                        task: TaskId(1),
                        addr: "10.0.0.1:9000".into(),
                        alts: vec!["10.0.0.2:9000".into(), "10.0.0.3:9000".into()],
                        nbytes: 500,
                    },
                    TaskInputLoc {
                        task: TaskId(2),
                        addr: String::new(),
                        alts: vec![],
                        nbytes: 10,
                    },
                ],
                priority: 3,
                consumers: 7,
                cores: 1,
            },
            // Resource-era compute-task: a multi-core slot reservation.
            Msg::ComputeTask {
                run: RunId(2),
                task: TaskId(44),
                key: "wide-44".into(),
                payload: Payload::BusyWait,
                duration_us: 9000,
                output_size: 16,
                inputs: vec![],
                priority: 1,
                consumers: 2,
                cores: 4,
            },
            // Incremental graph extension: a batch continuing the dense id
            // space at base 3, plus a pure close (empty batch, last=true).
            Msg::SubmitExtend {
                run: RunId(6),
                tasks: vec![
                    TaskSpec {
                        id: TaskId(3),
                        key: "ext-3".into(),
                        inputs: vec![TaskId(0), TaskId(2)],
                        duration_us: 10,
                        output_size: 20,
                        payload: Payload::MergeInputs,
                        cores: 1,
                    },
                    TaskSpec {
                        id: TaskId(4),
                        key: "ext-4".into(),
                        inputs: vec![TaskId(3)],
                        duration_us: 11,
                        output_size: 21,
                        payload: Payload::NoOp,
                        cores: 2,
                    },
                ],
                last: false,
            },
            Msg::SubmitExtend { run: RunId(6), tasks: vec![], last: true },
            Msg::PinData { run: RunId(6), task: TaskId(2), consumers: 3 },
            Msg::TaskFinished(TaskFinishedInfo {
                run: RunId(2),
                task: TaskId(9),
                nbytes: 27,
                duration_us: 6,
            }),
            Msg::TaskErred { run: RunId(0), task: TaskId(3), error: "oom".into() },
            Msg::StealRequest { run: RunId(1), task: TaskId(5) },
            Msg::StealResponse { run: RunId(1), task: TaskId(5), ok: false },
            Msg::StealResponse { run: RunId(1), task: TaskId(6), ok: true },
            Msg::CancelCompute { run: RunId(1), task: TaskId(7) },
            Msg::ReplicateData {
                run: RunId(5),
                task: TaskId(12),
                addrs: vec!["10.0.0.2:9000".into(), "10.0.0.3:9000".into()],
            },
            Msg::ReplicateData { run: RunId(5), task: TaskId(13), addrs: vec![] },
            Msg::PutData { run: RunId(5), task: TaskId(12), data: vec![4, 5, 6] },
            Msg::ReplicaAdded { run: RunId(5), task: TaskId(12) },
            Msg::ReplicaDropped { run: RunId(5), task: TaskId(12) },
            Msg::FetchData { run: RunId(4), task: TaskId(8) },
            Msg::FetchDataMany { run: RunId(4), tasks: vec![] },
            Msg::FetchDataMany { run: RunId(4), tasks: vec![TaskId(8), TaskId(2), TaskId(8)] },
            // 16+ entries crosses the fixarray boundary (0xdc array16).
            Msg::FetchDataMany { run: RunId(4), tasks: (0..20).map(TaskId).collect() },
            Msg::DataReply { run: RunId(4), task: TaskId(8), data: vec![1, 2, 3] },
            Msg::FetchFromServer { run: RunId(4), task: TaskId(8) },
            Msg::DataToServer { run: RunId(4), task: TaskId(8), data: vec![9; 100] },
            Msg::Shutdown,
            Msg::Heartbeat,
        ]
    }

    #[test]
    fn all_messages_roundtrip() {
        for m in all_test_messages() {
            rt(m);
        }
    }

    #[test]
    fn data_frame_head_payload_tail_matches_owned_encoding() {
        // The zero-copy serve path emits head, payload, and tail as three
        // separate writes; their concatenation must equal the owned
        // encoding at every bin length-format boundary, for every
        // data-bearing op.
        for len in [0usize, 1, 255, 256, 65_535, 65_536] {
            let data = vec![0x5au8; len];
            for op in ["data-reply", "put-data", "data-to-server"] {
                let (run, task) = (RunId(7), TaskId(90_000));
                let owned = match op {
                    "data-reply" => Msg::DataReply { run, task, data: data.clone() },
                    "put-data" => Msg::PutData { run, task, data: data.clone() },
                    _ => Msg::DataToServer { run, task, data: data.clone() },
                };
                let parts = DataFrameParts { op, run, task, data_len: len };
                let mut split = Vec::new();
                encode_data_frame_head(&parts, &mut split);
                split.extend_from_slice(&data);
                encode_data_frame_tail(&parts, &mut split);
                assert_eq!(split, encode_msg(&owned), "{op} len {len}");
            }
        }
    }

    #[test]
    fn fetch_many_borrowed_encoder_matches_owned() {
        for n in [0usize, 1, 15, 16, 200] {
            let tasks: Vec<TaskId> = (0..n as u32).map(|i| TaskId(i * 3)).collect();
            let mut borrowed = Vec::new();
            encode_fetch_many_into(RunId(2), &tasks, &mut borrowed);
            assert_eq!(
                borrowed,
                encode_msg(&Msg::FetchDataMany { run: RunId(2), tasks }),
                "n {n}"
            );
        }
    }

    #[test]
    fn streaming_handles_wide_field_values() {
        // Values crossing every integer format boundary must stay
        // byte-identical between the codecs.
        for n in [0u64, 127, 128, 255, 256, 65_535, 65_536, u32::MAX as u64, u64::MAX / 2] {
            rt(Msg::TaskFinished(TaskFinishedInfo {
                run: RunId(3),
                task: TaskId(1),
                nbytes: n,
                duration_us: n,
            }));
        }
        for p in [0i64, -1, -32, -33, -129, -70_000, i64::MIN / 2, i64::MAX / 2] {
            rt(Msg::ComputeTask {
                run: RunId(0),
                task: TaskId(0),
                key: "k".into(),
                payload: Payload::NoOp,
                duration_us: 1,
                output_size: 1,
                inputs: vec![],
                priority: p,
                consumers: 0,
                cores: 1,
            });
        }
        // Consumer counts across the uint format boundaries.
        for c in [1u32, 127, 128, 255, 256, 65_535, 65_536, u32::MAX] {
            rt(Msg::ComputeTask {
                run: RunId(0),
                task: TaskId(0),
                key: "k".into(),
                payload: Payload::NoOp,
                duration_us: 1,
                output_size: 1,
                inputs: vec![],
                priority: 0,
                consumers: c,
                cores: 1,
            });
        }
        // Core counts across the uint format boundaries (1 is the omitted
        // default; wider values must still agree between the codecs).
        for c in [2u32, 127, 128, 255, 256, 65_536] {
            rt(Msg::ComputeTask {
                run: RunId(0),
                task: TaskId(0),
                key: "k".into(),
                payload: Payload::NoOp,
                duration_us: 1,
                output_size: 1,
                inputs: vec![],
                priority: 0,
                consumers: 0,
                cores: c,
            });
        }
    }

    #[test]
    fn run_ids_distinguish_identical_task_ids() {
        // Same TaskId under two runs must decode to distinct messages —
        // the wire-level half of the multi-graph aliasing guarantee.
        let a = Msg::StealRequest { run: RunId(0), task: TaskId(5) };
        let b = Msg::StealRequest { run: RunId(1), task: TaskId(5) };
        assert_ne!(a, b);
        assert_ne!(encode_msg(&a), encode_msg(&b));
        assert_eq!(decode_msg(&encode_msg(&a)).unwrap(), a);
        assert_eq!(decode_msg(&encode_msg(&b)).unwrap(), b);
    }

    #[test]
    fn task_messages_without_run_are_rejected() {
        // A pre-RunId peer (or corrupted frame) must surface a typed error,
        // not silently alias run 0.
        let v = Value::map(vec![("op", Value::str("steal-request")), ("task", Value::from(5u32))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::Missing("run"))));
    }

    #[test]
    fn all_payload_kinds_roundtrip() {
        for p in [
            Payload::NoOp,
            Payload::BusyWait,
            Payload::MergeInputs,
            Payload::HloReduce { rows: 8, cols: 128, seed: 1 },
            Payload::HloTranspose { n: 32, seed: 2 },
            Payload::HloHash { n_tokens: 100, buckets: 1024, seed: 3 },
            Payload::WordBag { n_docs: 50, seed: 4 },
        ] {
            let back = payload_from_value(&payload_to_value(&p)).unwrap();
            assert_eq!(back, p);
            // And through the streaming pair, byte-identical to the tree.
            rt(Msg::ComputeTask {
                run: RunId(1),
                task: TaskId(2),
                key: "k".into(),
                payload: p,
                duration_us: 3,
                output_size: 4,
                inputs: vec![],
                priority: 5,
                consumers: 0,
                cores: 1,
            });
        }
    }

    #[test]
    fn graph_roundtrips_exactly() {
        for g in [graphgen::merge(50), graphgen::tree(5), graphgen::xarray(25)] {
            let v = graph_to_value(&g);
            let back = graph_from_value(&v).unwrap();
            assert_eq!(back.name, g.name);
            assert_eq!(back.len(), g.len());
            assert_eq!(back.n_deps(), g.n_deps());
            for (a, b) in back.tasks().iter().zip(g.tasks()) {
                assert_eq!(a.key, b.key);
                assert_eq!(a.inputs, b.inputs);
                assert_eq!(a.duration_us, b.duration_us);
                assert_eq!(a.output_size, b.output_size);
                assert_eq!(a.payload, b.payload);
                assert_eq!(a.cores, b.cores);
            }
            rt(Msg::SubmitGraph { graph: g, scheduler: None, open: false });
        }
    }

    #[test]
    fn submit_graph_scheduler_roundtrip() {
        rt(Msg::SubmitGraph {
            graph: graphgen::merge(5),
            scheduler: Some("random".into()),
            open: false,
        });
        // Absent scheduler decodes as None (wire compat with pre-field
        // frames).
        let m = Msg::SubmitGraph { graph: graphgen::merge(3), scheduler: None, open: false };
        let back = decode_msg(&encode_msg(&m)).unwrap();
        assert!(matches!(back, Msg::SubmitGraph { scheduler: None, .. }));
        // Wrong type is rejected, not ignored.
        let mut v = match decode(&encode_msg(&m)).unwrap() {
            Value::Map(map) => map,
            _ => unreachable!(),
        };
        v.insert("scheduler".into(), Value::Int(3));
        assert!(matches!(
            decode_msg(&encode(&Value::Map(v))),
            Err(CodecError::WrongType("scheduler"))
        ));
    }

    #[test]
    fn submit_graph_open_roundtrip_and_wire_compat() {
        rt(Msg::SubmitGraph { graph: graphgen::merge(4), scheduler: None, open: true });
        // `open: false` is omitted on the wire: the frame must be
        // byte-identical to a pre-extension encoder's output, and absent
        // `open` decodes as false.
        let closed = Msg::SubmitGraph { graph: graphgen::merge(4), scheduler: None, open: false };
        let bytes = encode_msg(&closed);
        let Value::Map(m) = decode(&bytes).unwrap() else { panic!("not a map") };
        assert!(!m.contains_key("open"));
        assert!(matches!(decode_msg(&bytes).unwrap(), Msg::SubmitGraph { open: false, .. }));
        // Wrong type is rejected, not ignored.
        let mut m = m;
        m.insert("open".into(), Value::Int(1));
        assert!(matches!(
            decode_msg(&encode(&Value::Map(m))),
            Err(CodecError::WrongType("open"))
        ));
    }

    #[test]
    fn submit_extend_reconstructs_dense_ids() {
        // The wire carries `base` + per-task maps; the decoder must hand
        // back the same dense TaskIds the encoder started from.
        let m = Msg::SubmitExtend {
            run: RunId(9),
            tasks: vec![
                TaskSpec {
                    id: TaskId(100),
                    key: "a".into(),
                    inputs: vec![TaskId(7)],
                    duration_us: 1,
                    output_size: 2,
                    payload: Payload::NoOp,
                    cores: 1,
                },
                TaskSpec {
                    id: TaskId(101),
                    key: "b".into(),
                    inputs: vec![TaskId(100)],
                    duration_us: 3,
                    output_size: 4,
                    payload: Payload::BusyWait,
                    cores: 4,
                },
            ],
            last: true,
        };
        rt(m.clone());
        let back = decode_msg(&encode_msg(&m)).unwrap();
        let Msg::SubmitExtend { tasks, .. } = back else { panic!("wrong op") };
        assert_eq!(tasks[0].id, TaskId(100));
        assert_eq!(tasks[1].id, TaskId(101));
        assert_eq!(tasks[1].cores, 4);
        // `cores: 1` is omitted from the task map (wire compat with the
        // submit-graph task encoding).
        let bytes = encode_msg(&m);
        let v = decode(&bytes).unwrap();
        let t0 = &v.get("tasks").unwrap().as_array().unwrap()[0];
        assert!(t0.get("cores").is_none());
    }

    #[test]
    fn malicious_graph_rejected() {
        // Build a value whose task 0 depends on task 1 (forward ref/cycle).
        let g = graphgen::merge(2);
        let mut v = graph_to_value(&g);
        if let Value::Map(m) = &mut v {
            if let Some(Value::Array(tasks)) = m.get_mut("tasks") {
                if let Value::Map(t0) = &mut tasks[0] {
                    t0.insert("inputs".into(), Value::Array(vec![Value::from(1u32)]));
                }
            }
        }
        assert!(matches!(graph_from_value(&v), Err(CodecError::Graph(_))));
    }

    #[test]
    fn decode_errors_are_typed() {
        assert!(matches!(decode_msg(&[0xff, 0xfe]), Err(CodecError::Msgpack(_))));
        let v = Value::map(vec![("op", Value::str("no-such-op"))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::UnknownOp(_))));
        let v = Value::map(vec![("op", Value::str("welcome"))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::Missing("id"))));
        let v = Value::map(vec![("op", Value::str("welcome")), ("id", Value::str("x"))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::WrongType("id"))));
        // Missing op entirely.
        let v = Value::map(vec![("id", Value::from(1u32))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::Missing("op"))));
    }

    #[test]
    fn truncated_frames_error_never_panic() {
        for m in all_test_messages() {
            let bytes = encode_msg(&m);
            for cut in 0..bytes.len() {
                assert!(
                    decode_msg(&bytes[..cut]).is_err(),
                    "truncated {op} at {cut}/{} must error",
                    bytes.len(),
                    op = m.op()
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        for m in [
            Msg::Heartbeat,
            Msg::StealRequest { run: RunId(1), task: TaskId(5) },
            Msg::TaskFinished(TaskFinishedInfo {
                run: RunId(2),
                task: TaskId(9),
                nbytes: 27,
                duration_us: 6,
            }),
        ] {
            let mut bytes = encode_msg(&m);
            bytes.push(0x00);
            assert!(
                matches!(
                    decode_msg(&bytes),
                    Err(CodecError::Msgpack(DecodeError::Trailing(1)))
                ),
                "{op}",
                op = m.op()
            );
        }
    }

    #[test]
    fn unknown_fields_are_skipped() {
        // Forward compatibility: a newer peer may add fields; older decoders
        // must step over them.
        let v = Value::map(vec![
            ("op", Value::str("steal-request")),
            ("run", Value::from(1u32)),
            ("task", Value::from(5u32)),
            ("zz_future_field", Value::Array(vec![Value::str("x"), Value::Nil])),
        ]);
        assert_eq!(
            decode_msg(&encode(&v)).unwrap(),
            Msg::StealRequest { run: RunId(1), task: TaskId(5) }
        );
    }

    #[test]
    fn compute_task_view_matches_owned_decode() {
        let m = Msg::ComputeTask {
            run: RunId(11),
            task: TaskId(77),
            key: "xarray-77".into(),
            payload: Payload::HloHash { n_tokens: 9, buckets: 64, seed: 3 },
            duration_us: 123,
            output_size: 456,
            inputs: vec![
                TaskInputLoc {
                    task: TaskId(70),
                    addr: "10.0.0.2:9000".into(),
                    alts: vec!["10.0.0.3:9000".into()],
                    nbytes: 11,
                },
                TaskInputLoc {
                    task: TaskId(71),
                    addr: String::new(),
                    alts: vec![],
                    nbytes: 22,
                },
            ],
            priority: -9,
            consumers: 4,
            cores: 2,
        };
        let bytes = encode_msg(&m);
        let view = ComputeTaskView::decode(&bytes).unwrap();
        let decoded = decode_msg(&bytes).unwrap();
        let Msg::ComputeTask {
            run,
            task,
            key,
            payload,
            duration_us,
            output_size,
            inputs,
            priority,
            consumers,
            cores,
        } = decoded
        else {
            panic!("wrong op");
        };
        assert_eq!(view.run, run);
        assert_eq!(view.task, task);
        assert_eq!(view.key, key);
        assert_eq!(view.payload, payload);
        assert_eq!(view.duration_us, duration_us);
        assert_eq!(view.output_size, output_size);
        assert_eq!(view.priority, priority);
        assert_eq!(view.consumers, consumers);
        assert_eq!(view.cores, cores);
        assert_eq!(view.n_inputs(), inputs.len());
        let got: Vec<TaskInputRef> = view.inputs().collect::<Result<_, _>>().unwrap();
        for (g, w) in got.iter().zip(&inputs) {
            assert_eq!(g.task, w.task);
            assert_eq!(g.addr, w.addr);
            assert_eq!(g.nbytes, w.nbytes);
            let galts: Vec<&str> = g.alts().to_vec();
            let walts: Vec<&str> = w.alts.iter().map(String::as_str).collect();
            assert_eq!(galts, walts);
        }
        // The view rejects other ops.
        let other = encode_msg(&Msg::Heartbeat);
        assert!(ComputeTaskView::decode(&other).is_err());
    }

    #[test]
    fn alt_addrs_truncate_at_protocol_cap() {
        // A frame carrying more than MAX_ALT_ADDRS alternates (hand-built;
        // our encoders never produce one) must decode identically through
        // the owned, borrowed, and Value-tree decoders: the first
        // MAX_ALT_ADDRS entries, the rest dropped.
        let long: Vec<Value> =
            (0..MAX_ALT_ADDRS + 2).map(|i| Value::str(&format!("10.0.0.{i}:9"))).collect();
        let v = Value::map(vec![
            ("op", Value::str("compute-task")),
            ("run", Value::from(1u32)),
            ("task", Value::from(2u32)),
            ("key", Value::str("k")),
            ("payload", Value::map(vec![("kind", Value::str("noop"))])),
            ("duration_us", Value::from(1u64)),
            ("output_size", Value::from(1u64)),
            ("priority", Value::Int(0)),
            (
                "inputs",
                Value::Array(vec![Value::map(vec![
                    ("task", Value::from(0u32)),
                    ("addr", Value::str("10.0.0.9:9")),
                    ("alts", Value::Array(long)),
                    ("nbytes", Value::from(5u64)),
                ])]),
            ),
        ]);
        let bytes = encode(&v);
        let want: Vec<String> =
            (0..MAX_ALT_ADDRS).map(|i| format!("10.0.0.{i}:9")).collect();
        for decoded in [decode_msg(&bytes).unwrap(), decode_msg_value(&bytes).unwrap()] {
            let Msg::ComputeTask { inputs, .. } = decoded else { panic!("wrong op") };
            assert_eq!(inputs[0].alts, want);
        }
        let view = ComputeTaskView::decode(&bytes).unwrap();
        let got: Vec<TaskInputRef> = view.inputs().collect::<Result<_, _>>().unwrap();
        assert_eq!(got[0].alts().to_vec(), want.iter().map(String::as_str).collect::<Vec<_>>());
    }

    #[test]
    fn borrowed_parts_encode_matches_owned() {
        // The dispatch hot path encodes from ComputeTaskParts + borrowed
        // inputs; the bytes must equal the owned encode (and therefore the
        // Value-tree reference, by the existing identity tests).
        let inputs = vec![
            TaskInputLoc {
                task: TaskId(70),
                addr: "10.0.0.2:9000".into(),
                alts: vec!["10.0.0.4:9000".into(), "10.0.0.5:9000".into()],
                nbytes: 11,
            },
            TaskInputLoc { task: TaskId(71), addr: String::new(), alts: vec![], nbytes: 22 },
        ];
        let m = Msg::ComputeTask {
            run: RunId(11),
            task: TaskId(77),
            key: "xarray-77".into(),
            payload: Payload::HloHash { n_tokens: 9, buckets: 64, seed: 3 },
            duration_us: 123,
            output_size: 456,
            inputs: inputs.clone(),
            priority: -9,
            consumers: 2,
            cores: 3,
        };
        let owned = encode_msg(&m);
        let parts = ComputeTaskParts {
            run: RunId(11),
            task: TaskId(77),
            key: "xarray-77",
            payload: &Payload::HloHash { n_tokens: 9, buckets: 64, seed: 3 },
            duration_us: 123,
            output_size: 456,
            priority: -9,
            consumers: 2,
            cores: 3,
        };
        let mut borrowed = Vec::new();
        encode_compute_task_into(
            &parts,
            inputs.iter().map(|l| {
                let mut r = TaskInputRef::new(l.task, &l.addr, l.nbytes);
                for a in &l.alts {
                    r.push_alt(a);
                }
                r
            }),
            &mut borrowed,
        );
        assert_eq!(borrowed, owned);
        // And it round-trips through both decoders.
        assert_eq!(decode_msg(&borrowed).unwrap(), m);
        let view = ComputeTaskView::decode(&borrowed).unwrap();
        assert_eq!(view.key, "xarray-77");
        assert_eq!(view.n_inputs(), 2);
    }

    #[test]
    fn peek_op_names_every_message() {
        for m in all_test_messages() {
            let bytes = encode_msg(&m);
            assert_eq!(peek_op(&bytes).unwrap(), m.op());
        }
        assert!(peek_op(&[0xff]).is_err());
    }

    #[test]
    fn compute_task_message_size_is_small() {
        // The per-task message must stay in the hundreds of bytes — it is
        // multiplied by 100k tasks in merge-100K.
        let bytes = encode_msg(&Msg::ComputeTask {
            run: RunId(41),
            task: TaskId(99_999),
            key: "task-99999".into(),
            payload: Payload::BusyWait,
            duration_us: 6,
            output_size: 28,
            inputs: vec![],
            priority: 99_999,
            consumers: 1,
            cores: 1,
        });
        assert!(bytes.len() < 256, "compute-task message is {} bytes", bytes.len());
    }

    #[test]
    fn encode_into_reuses_buffer_without_growth() {
        // After one warm-up encode the reused buffer must not reallocate:
        // capacity stays put while repeated encodes produce identical bytes.
        let m = Msg::TaskFinished(TaskFinishedInfo {
            run: RunId(2),
            task: TaskId(9),
            nbytes: 27,
            duration_us: 6,
        });
        let mut buf = Vec::new();
        encode_msg_into(&m, &mut buf);
        let first = buf.clone();
        let cap = buf.capacity();
        for _ in 0..100 {
            buf.clear();
            encode_msg_into(&m, &mut buf);
            assert_eq!(buf, first);
        }
        assert_eq!(buf.capacity(), cap, "warm encode must not grow the buffer");
    }
}
