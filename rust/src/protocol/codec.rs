//! Msg ⇄ msgpack conversion, including the task-graph encoding carried by
//! `submit-graph`. Static message structure throughout (§IV-B).

use super::messages::{Msg, RunId, TaskFinishedInfo, TaskInputLoc};
use crate::msgpack::{decode, encode, DecodeError, Value};
use crate::taskgraph::{GraphError, Payload, TaskGraph, TaskId, TaskSpec};

#[derive(Debug, thiserror::Error)]
pub enum CodecError {
    #[error("msgpack: {0}")]
    Msgpack(#[from] DecodeError),
    #[error("message missing field {0:?}")]
    Missing(&'static str),
    #[error("field {0:?} has wrong type")]
    WrongType(&'static str),
    #[error("unknown op {0:?}")]
    UnknownOp(String),
    #[error("unknown payload kind {0:?}")]
    UnknownPayload(String),
    #[error("invalid graph: {0}")]
    Graph(#[from] GraphError),
}

// ---------- helpers ----------

fn get<'a>(v: &'a Value, k: &'static str) -> Result<&'a Value, CodecError> {
    v.get(k).ok_or(CodecError::Missing(k))
}

fn get_str(v: &Value, k: &'static str) -> Result<String, CodecError> {
    get(v, k)?.as_str().map(str::to_string).ok_or(CodecError::WrongType(k))
}

fn get_u64(v: &Value, k: &'static str) -> Result<u64, CodecError> {
    get(v, k)?.as_u64().ok_or(CodecError::WrongType(k))
}

fn get_i64(v: &Value, k: &'static str) -> Result<i64, CodecError> {
    get(v, k)?.as_i64().ok_or(CodecError::WrongType(k))
}

fn get_bool(v: &Value, k: &'static str) -> Result<bool, CodecError> {
    get(v, k)?.as_bool().ok_or(CodecError::WrongType(k))
}

fn get_bin(v: &Value, k: &'static str) -> Result<Vec<u8>, CodecError> {
    get(v, k)?.as_bin().map(<[u8]>::to_vec).ok_or(CodecError::WrongType(k))
}

fn get_task(v: &Value, k: &'static str) -> Result<TaskId, CodecError> {
    Ok(TaskId(get_u64(v, k)? as u32))
}

fn get_run(v: &Value) -> Result<RunId, CodecError> {
    Ok(RunId(get_u64(v, "run")? as u32))
}

// ---------- payload ----------

fn payload_to_value(p: &Payload) -> Value {
    match p {
        Payload::NoOp => Value::map(vec![("kind", Value::str("noop"))]),
        Payload::BusyWait => Value::map(vec![("kind", Value::str("busywait"))]),
        Payload::MergeInputs => Value::map(vec![("kind", Value::str("merge"))]),
        Payload::HloReduce { rows, cols, seed } => Value::map(vec![
            ("kind", Value::str("hlo-reduce")),
            ("rows", Value::from(*rows)),
            ("cols", Value::from(*cols)),
            ("seed", Value::from(*seed)),
        ]),
        Payload::HloTranspose { n, seed } => Value::map(vec![
            ("kind", Value::str("hlo-transpose")),
            ("n", Value::from(*n)),
            ("seed", Value::from(*seed)),
        ]),
        Payload::HloHash { n_tokens, buckets, seed } => Value::map(vec![
            ("kind", Value::str("hlo-hash")),
            ("n_tokens", Value::from(*n_tokens)),
            ("buckets", Value::from(*buckets)),
            ("seed", Value::from(*seed)),
        ]),
        Payload::WordBag { n_docs, seed } => Value::map(vec![
            ("kind", Value::str("wordbag")),
            ("n_docs", Value::from(*n_docs)),
            ("seed", Value::from(*seed)),
        ]),
    }
}

fn payload_from_value(v: &Value) -> Result<Payload, CodecError> {
    let kind = get_str(v, "kind")?;
    Ok(match kind.as_str() {
        "noop" => Payload::NoOp,
        "busywait" => Payload::BusyWait,
        "merge" => Payload::MergeInputs,
        "hlo-reduce" => Payload::HloReduce {
            rows: get_u64(v, "rows")? as u32,
            cols: get_u64(v, "cols")? as u32,
            seed: get_u64(v, "seed")?,
        },
        "hlo-transpose" => {
            Payload::HloTranspose { n: get_u64(v, "n")? as u32, seed: get_u64(v, "seed")? }
        }
        "hlo-hash" => Payload::HloHash {
            n_tokens: get_u64(v, "n_tokens")? as u32,
            buckets: get_u64(v, "buckets")? as u32,
            seed: get_u64(v, "seed")?,
        },
        "wordbag" => {
            Payload::WordBag { n_docs: get_u64(v, "n_docs")? as u32, seed: get_u64(v, "seed")? }
        }
        other => return Err(CodecError::UnknownPayload(other.to_string())),
    })
}

// ---------- graph ----------

/// Encode a task graph as a msgpack value (used in `submit-graph`).
pub fn graph_to_value(g: &TaskGraph) -> Value {
    let tasks: Vec<Value> = g
        .tasks()
        .iter()
        .map(|t| {
            Value::map(vec![
                ("key", Value::str(&t.key)),
                (
                    "inputs",
                    Value::Array(t.inputs.iter().map(|i| Value::from(i.0)).collect()),
                ),
                ("duration_us", Value::from(t.duration_us)),
                ("output_size", Value::from(t.output_size)),
                ("payload", payload_to_value(&t.payload)),
            ])
        })
        .collect();
    Value::map(vec![("name", Value::str(&g.name)), ("tasks", Value::Array(tasks))])
}

/// Decode a task graph (validates DAG invariants on arrival — a malicious
/// client cannot install a cyclic graph).
pub fn graph_from_value(v: &Value) -> Result<TaskGraph, CodecError> {
    let name = get_str(v, "name")?;
    let tasks_v = get(v, "tasks")?.as_array().ok_or(CodecError::WrongType("tasks"))?;
    let mut tasks = Vec::with_capacity(tasks_v.len());
    for (i, tv) in tasks_v.iter().enumerate() {
        let inputs_v = get(tv, "inputs")?.as_array().ok_or(CodecError::WrongType("inputs"))?;
        let inputs = inputs_v
            .iter()
            .map(|x| x.as_u64().map(|u| TaskId(u as u32)).ok_or(CodecError::WrongType("inputs")))
            .collect::<Result<Vec<_>, _>>()?;
        tasks.push(TaskSpec {
            id: TaskId(i as u32),
            key: get_str(tv, "key")?,
            inputs,
            duration_us: get_u64(tv, "duration_us")?,
            output_size: get_u64(tv, "output_size")?,
            payload: payload_from_value(get(tv, "payload")?)?,
        });
    }
    Ok(TaskGraph::new(name, tasks)?)
}

// ---------- messages ----------

/// Encode a message to framed-ready bytes.
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    let mut fields: Vec<(&str, Value)> = vec![("op", Value::str(msg.op()))];
    match msg {
        Msg::RegisterClient { name } => fields.push(("name", Value::str(name))),
        Msg::RegisterWorker { name, ncores, node, data_addr } => {
            fields.push(("name", Value::str(name)));
            fields.push(("ncores", Value::from(*ncores)));
            fields.push(("node", Value::from(*node)));
            fields.push(("data_addr", Value::str(data_addr)));
        }
        Msg::Welcome { id } => fields.push(("id", Value::from(*id))),
        Msg::SubmitGraph { graph } => fields.push(("graph", graph_to_value(graph))),
        Msg::GraphSubmitted { run, n_tasks } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("n_tasks", Value::from(*n_tasks)));
        }
        Msg::GraphDone { run, makespan_us, n_tasks } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("makespan_us", Value::from(*makespan_us)));
            fields.push(("n_tasks", Value::from(*n_tasks)));
        }
        Msg::GraphFailed { run, reason } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("reason", Value::str(reason)));
        }
        Msg::ReleaseRun { run } => fields.push(("run", Value::from(run.0))),
        Msg::ComputeTask { run, task, key, payload, duration_us, output_size, inputs, priority } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("key", Value::str(key)));
            fields.push(("payload", payload_to_value(payload)));
            fields.push(("duration_us", Value::from(*duration_us)));
            fields.push(("output_size", Value::from(*output_size)));
            fields.push((
                "inputs",
                Value::Array(
                    inputs
                        .iter()
                        .map(|l| {
                            Value::map(vec![
                                ("task", Value::from(l.task.0)),
                                ("addr", Value::str(&l.addr)),
                                ("nbytes", Value::from(l.nbytes)),
                            ])
                        })
                        .collect(),
                ),
            ));
            fields.push(("priority", Value::Int(*priority)));
        }
        Msg::TaskFinished(info) => {
            fields.push(("run", Value::from(info.run.0)));
            fields.push(("task", Value::from(info.task.0)));
            fields.push(("nbytes", Value::from(info.nbytes)));
            fields.push(("duration_us", Value::from(info.duration_us)));
        }
        Msg::TaskErred { run, task, error } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("error", Value::str(error)));
        }
        Msg::StealRequest { run, task } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
        }
        Msg::StealResponse { run, task, ok } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("ok", Value::Bool(*ok)));
        }
        Msg::FetchData { run, task } | Msg::FetchFromServer { run, task } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
        }
        Msg::DataReply { run, task, data } | Msg::DataToServer { run, task, data } => {
            fields.push(("run", Value::from(run.0)));
            fields.push(("task", Value::from(task.0)));
            fields.push(("data", Value::Bin(data.clone())));
        }
        Msg::Shutdown | Msg::Heartbeat => {}
    }
    encode(&Value::map(fields))
}

/// Decode one message from bytes.
pub fn decode_msg(bytes: &[u8]) -> Result<Msg, CodecError> {
    let v = decode(bytes)?;
    let op = get_str(&v, "op")?;
    Ok(match op.as_str() {
        "register-client" => Msg::RegisterClient { name: get_str(&v, "name")? },
        "register-worker" => Msg::RegisterWorker {
            name: get_str(&v, "name")?,
            ncores: get_u64(&v, "ncores")? as u32,
            node: get_u64(&v, "node")? as u32,
            data_addr: get_str(&v, "data_addr")?,
        },
        "welcome" => Msg::Welcome { id: get_u64(&v, "id")? as u32 },
        "submit-graph" => Msg::SubmitGraph { graph: graph_from_value(get(&v, "graph")?)? },
        "graph-submitted" => {
            Msg::GraphSubmitted { run: get_run(&v)?, n_tasks: get_u64(&v, "n_tasks")? }
        }
        "graph-done" => Msg::GraphDone {
            run: get_run(&v)?,
            makespan_us: get_u64(&v, "makespan_us")?,
            n_tasks: get_u64(&v, "n_tasks")?,
        },
        "graph-failed" => {
            Msg::GraphFailed { run: get_run(&v)?, reason: get_str(&v, "reason")? }
        }
        "release-run" => Msg::ReleaseRun { run: get_run(&v)? },
        "compute-task" => {
            let inputs_v =
                get(&v, "inputs")?.as_array().ok_or(CodecError::WrongType("inputs"))?;
            let inputs = inputs_v
                .iter()
                .map(|l| {
                    Ok(TaskInputLoc {
                        task: get_task(l, "task")?,
                        addr: get_str(l, "addr")?,
                        nbytes: get_u64(l, "nbytes")?,
                    })
                })
                .collect::<Result<Vec<_>, CodecError>>()?;
            Msg::ComputeTask {
                run: get_run(&v)?,
                task: get_task(&v, "task")?,
                key: get_str(&v, "key")?,
                payload: payload_from_value(get(&v, "payload")?)?,
                duration_us: get_u64(&v, "duration_us")?,
                output_size: get_u64(&v, "output_size")?,
                inputs,
                priority: get_i64(&v, "priority")?,
            }
        }
        "task-finished" => Msg::TaskFinished(TaskFinishedInfo {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            nbytes: get_u64(&v, "nbytes")?,
            duration_us: get_u64(&v, "duration_us")?,
        }),
        "task-erred" => Msg::TaskErred {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            error: get_str(&v, "error")?,
        },
        "steal-request" => Msg::StealRequest { run: get_run(&v)?, task: get_task(&v, "task")? },
        "steal-response" => Msg::StealResponse {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            ok: get_bool(&v, "ok")?,
        },
        "fetch-data" => Msg::FetchData { run: get_run(&v)?, task: get_task(&v, "task")? },
        "data-reply" => Msg::DataReply {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            data: get_bin(&v, "data")?,
        },
        "fetch-from-server" => {
            Msg::FetchFromServer { run: get_run(&v)?, task: get_task(&v, "task")? }
        }
        "data-to-server" => Msg::DataToServer {
            run: get_run(&v)?,
            task: get_task(&v, "task")?,
            data: get_bin(&v, "data")?,
        },
        "shutdown" => Msg::Shutdown,
        "heartbeat" => Msg::Heartbeat,
        other => return Err(CodecError::UnknownOp(other.to_string())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen;

    fn rt(m: Msg) {
        let bytes = encode_msg(&m);
        let back = decode_msg(&bytes).unwrap_or_else(|e| panic!("{m:?}: {e}"));
        assert_eq!(back, m);
    }

    #[test]
    fn all_messages_roundtrip() {
        rt(Msg::RegisterClient { name: "client-1".into() });
        rt(Msg::RegisterWorker {
            name: "w3".into(),
            ncores: 1,
            node: 2,
            data_addr: "127.0.0.1:9123".into(),
        });
        rt(Msg::Welcome { id: 17 });
        rt(Msg::GraphSubmitted { run: RunId(3), n_tasks: 10_001 });
        rt(Msg::GraphDone { run: RunId(3), makespan_us: 123_456, n_tasks: 10_001 });
        rt(Msg::GraphFailed { run: RunId(7), reason: "worker died".into() });
        rt(Msg::ReleaseRun { run: RunId(7) });
        rt(Msg::ComputeTask {
            run: RunId(2),
            task: TaskId(42),
            key: "merge-42".into(),
            payload: Payload::HloReduce { rows: 64, cols: 128, seed: 7 },
            duration_us: 1000,
            output_size: 2048,
            inputs: vec![
                TaskInputLoc { task: TaskId(1), addr: "10.0.0.1:9000".into(), nbytes: 500 },
                TaskInputLoc { task: TaskId(2), addr: String::new(), nbytes: 10 },
            ],
            priority: -5,
        });
        rt(Msg::TaskFinished(TaskFinishedInfo {
            run: RunId(2),
            task: TaskId(9),
            nbytes: 27,
            duration_us: 6,
        }));
        rt(Msg::TaskErred { run: RunId(0), task: TaskId(3), error: "oom".into() });
        rt(Msg::StealRequest { run: RunId(1), task: TaskId(5) });
        rt(Msg::StealResponse { run: RunId(1), task: TaskId(5), ok: false });
        rt(Msg::FetchData { run: RunId(4), task: TaskId(8) });
        rt(Msg::DataReply { run: RunId(4), task: TaskId(8), data: vec![1, 2, 3] });
        rt(Msg::FetchFromServer { run: RunId(4), task: TaskId(8) });
        rt(Msg::DataToServer { run: RunId(4), task: TaskId(8), data: vec![9; 100] });
        rt(Msg::Shutdown);
        rt(Msg::Heartbeat);
    }

    #[test]
    fn run_ids_distinguish_identical_task_ids() {
        // Same TaskId under two runs must decode to distinct messages —
        // the wire-level half of the multi-graph aliasing guarantee.
        let a = Msg::StealRequest { run: RunId(0), task: TaskId(5) };
        let b = Msg::StealRequest { run: RunId(1), task: TaskId(5) };
        assert_ne!(a, b);
        assert_ne!(encode_msg(&a), encode_msg(&b));
        assert_eq!(decode_msg(&encode_msg(&a)).unwrap(), a);
        assert_eq!(decode_msg(&encode_msg(&b)).unwrap(), b);
    }

    #[test]
    fn task_messages_without_run_are_rejected() {
        // A pre-RunId peer (or corrupted frame) must surface a typed error,
        // not silently alias run 0.
        let v = Value::map(vec![("op", Value::str("steal-request")), ("task", Value::from(5u32))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::Missing("run"))));
    }

    #[test]
    fn all_payload_kinds_roundtrip() {
        for p in [
            Payload::NoOp,
            Payload::BusyWait,
            Payload::MergeInputs,
            Payload::HloReduce { rows: 8, cols: 128, seed: 1 },
            Payload::HloTranspose { n: 32, seed: 2 },
            Payload::HloHash { n_tokens: 100, buckets: 1024, seed: 3 },
            Payload::WordBag { n_docs: 50, seed: 4 },
        ] {
            let back = payload_from_value(&payload_to_value(&p)).unwrap();
            assert_eq!(back, p);
        }
    }

    #[test]
    fn graph_roundtrips_exactly() {
        for g in [graphgen::merge(50), graphgen::tree(5), graphgen::xarray(25)] {
            let v = graph_to_value(&g);
            let back = graph_from_value(&v).unwrap();
            assert_eq!(back.name, g.name);
            assert_eq!(back.len(), g.len());
            assert_eq!(back.n_deps(), g.n_deps());
            for (a, b) in back.tasks().iter().zip(g.tasks()) {
                assert_eq!(a.key, b.key);
                assert_eq!(a.inputs, b.inputs);
                assert_eq!(a.duration_us, b.duration_us);
                assert_eq!(a.output_size, b.output_size);
                assert_eq!(a.payload, b.payload);
            }
            rt(Msg::SubmitGraph { graph: g });
        }
    }

    #[test]
    fn malicious_graph_rejected() {
        // Build a value whose task 0 depends on task 1 (forward ref/cycle).
        let g = graphgen::merge(2);
        let mut v = graph_to_value(&g);
        if let Value::Map(m) = &mut v {
            if let Some(Value::Array(tasks)) = m.get_mut("tasks") {
                if let Value::Map(t0) = &mut tasks[0] {
                    t0.insert("inputs".into(), Value::Array(vec![Value::from(1u32)]));
                }
            }
        }
        assert!(matches!(graph_from_value(&v), Err(CodecError::Graph(_))));
    }

    #[test]
    fn decode_errors_are_typed() {
        assert!(matches!(decode_msg(&[0xff, 0xfe]), Err(CodecError::Msgpack(_))));
        let v = Value::map(vec![("op", Value::str("no-such-op"))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::UnknownOp(_))));
        let v = Value::map(vec![("op", Value::str("welcome"))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::Missing("id"))));
        let v = Value::map(vec![("op", Value::str("welcome")), ("id", Value::str("x"))]);
        assert!(matches!(decode_msg(&encode(&v)), Err(CodecError::WrongType("id"))));
    }

    #[test]
    fn compute_task_message_size_is_small() {
        // The per-task message must stay in the hundreds of bytes — it is
        // multiplied by 100k tasks in merge-100K.
        let bytes = encode_msg(&Msg::ComputeTask {
            run: RunId(41),
            task: TaskId(99_999),
            key: "task-99999".into(),
            payload: Payload::BusyWait,
            duration_us: 6,
            output_size: 28,
            inputs: vec![],
            priority: 99_999,
        });
        assert!(bytes.len() < 256, "compute-task message is {} bytes", bytes.len());
    }
}
