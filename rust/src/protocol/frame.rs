//! Length-prefixed framing over blocking byte streams.
//!
//! `[u64 le length][length bytes of msgpack]`. The length is validated
//! against [`MAX_FRAME_LEN`] before any allocation — a malicious or corrupt
//! peer cannot make the server allocate unbounded memory (exercised by the
//! failure-injection tests).

use std::io::{Read, Write};

/// Upper bound on a single frame (1 GiB) — larger than any legitimate
/// message (numpy partitions cap out around 128 MiB).
pub const MAX_FRAME_LEN: u64 = 1 << 30;

#[derive(Debug, thiserror::Error)]
pub enum FrameError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame of {0} bytes exceeds limit {MAX_FRAME_LEN}")]
    TooLarge(u64),
    #[error("peer closed the connection")]
    Closed,
}

/// Write one frame (length prefix + body).
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> Result<(), FrameError> {
    let len = body.len() as u64;
    if len > MAX_FRAME_LEN {
        return Err(FrameError::TooLarge(len));
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Returns `FrameError::Closed` on clean EOF at a frame
/// boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let mut len_buf = [0u8; 8];
    // Distinguish clean close (0 bytes) from mid-prefix truncation.
    let mut got = 0;
    while got < 8 {
        let n = r.read(&mut len_buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Err(FrameError::Closed);
            }
            return Err(FrameError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated frame length",
            )));
        }
        got += n;
    }
    let len = u64::from_le_bytes(len_buf);
    if len > MAX_FRAME_LEN {
        return Err(FrameError::TooLarge(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &vec![0xAB; 100_000]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap(), vec![0xAB; 100_000]);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn oversized_length_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        buf.extend_from_slice(b"xx");
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::TooLarge(_))));
    }

    #[test]
    fn truncated_prefix_is_io_error() {
        let mut r = Cursor::new(vec![1u8, 2, 3]); // 3 of 8 prefix bytes
        assert!(matches!(read_frame(&mut r), Err(FrameError::Io(_))));
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u64.to_le_bytes());
        buf.extend_from_slice(b"only5");
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Io(_))));
    }
}
