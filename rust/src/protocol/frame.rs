//! Length-prefixed framing over blocking byte streams.
//!
//! `[u64 le length][length bytes of msgpack]`. The length is validated
//! against [`MAX_FRAME_LEN`] before any allocation — a malicious or corrupt
//! peer cannot make the server allocate unbounded memory (exercised by the
//! failure-injection tests).

use std::io::{Read, Write};

/// Upper bound on a single frame (1 GiB) — larger than any legitimate
/// message (numpy partitions cap out around 128 MiB).
pub const MAX_FRAME_LEN: u64 = 1 << 30;

#[derive(Debug, thiserror::Error)]
pub enum FrameError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame of {0} bytes exceeds limit {MAX_FRAME_LEN}")]
    TooLarge(u64),
    #[error("peer closed the connection")]
    Closed,
}

/// Write one frame (length prefix + body).
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> Result<(), FrameError> {
    let len = body.len() as u64;
    if len > MAX_FRAME_LEN {
        return Err(FrameError::TooLarge(len));
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Returns `FrameError::Closed` on clean EOF at a frame
/// boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let len = read_frame_len(r)?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Read and validate a frame's length prefix.
fn read_frame_len(r: &mut impl Read) -> Result<usize, FrameError> {
    let mut len_buf = [0u8; 8];
    // Distinguish clean close (0 bytes) from mid-prefix truncation.
    let mut got = 0;
    while got < 8 {
        let n = r.read(&mut len_buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Err(FrameError::Closed);
            }
            return Err(FrameError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated frame length",
            )));
        }
        got += n;
    }
    let len = u64::from_le_bytes(len_buf);
    if len > MAX_FRAME_LEN {
        return Err(FrameError::TooLarge(len));
    }
    Ok(len as usize)
}

/// Append `msg` to `batch` as one complete frame (no I/O).
///
/// This is the server's coalescing primitive: the reactor appends every
/// frame bound for one connection into a single buffer and the writer
/// thread flushes it with one `write_all` — one syscall per flush instead
/// of two per message.
pub fn append_frame(batch: &mut Vec<u8>, msg: &super::Msg) -> Result<(), FrameError> {
    append_frame_with(batch, |body| super::codec::encode_msg_into(msg, body))
}

/// Append one frame whose body is produced by `encode` (length prefix
/// back-patched after the fact, no I/O). This is [`append_frame`] with the
/// encoder abstracted out so borrowed encoders — the server's
/// allocation-free compute-task dispatch — share the framing logic.
pub fn append_frame_with(
    batch: &mut Vec<u8>,
    encode: impl FnOnce(&mut Vec<u8>),
) -> Result<(), FrameError> {
    let start = batch.len();
    batch.extend_from_slice(&[0u8; 8]);
    encode(batch);
    let len = (batch.len() - start - 8) as u64;
    if len > MAX_FRAME_LEN {
        batch.truncate(start);
        return Err(FrameError::TooLarge(len));
    }
    batch[start..start + 8].copy_from_slice(&len.to_le_bytes());
    Ok(())
}

/// Reusable single-message frame writer: one internal buffer holds
/// `[len][msgpack body]`, written with a single `write_all`. A warm
/// [`FrameWriter::send`] performs zero heap allocations and one syscall —
/// the per-connection send path of workers and clients.
pub struct FrameWriter {
    buf: Vec<u8>,
}

impl FrameWriter {
    pub fn new() -> FrameWriter {
        FrameWriter { buf: Vec::new() }
    }

    /// Encode `msg` and write it as one frame.
    pub fn send(&mut self, w: &mut impl Write, msg: &super::Msg) -> Result<(), FrameError> {
        self.buf.clear();
        append_frame(&mut self.buf, msg)?;
        w.write_all(&self.buf)?;
        w.flush()?;
        Ok(())
    }
}

impl Default for FrameWriter {
    fn default() -> Self {
        FrameWriter::new()
    }
}

/// Reusable frame reader: the body buffer is reused across frames, so a
/// warm read allocates nothing (the buffer grows to the largest frame seen
/// and stays there).
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader { buf: Vec::new() }
    }

    /// Read one frame; the returned slice is valid until the next call.
    /// Returns `FrameError::Closed` on clean EOF at a frame boundary.
    pub fn read<'a>(&'a mut self, r: &mut impl Read) -> Result<&'a [u8], FrameError> {
        let len = read_frame_len(r)?;
        self.buf.clear();
        self.buf.resize(len, 0);
        r.read_exact(&mut self.buf)?;
        Ok(&self.buf)
    }
}

impl Default for FrameReader {
    fn default() -> Self {
        FrameReader::new()
    }
}

/// Outcome of one [`FrameAccumulator::poll_frame`] call against a
/// nonblocking stream.
#[derive(Debug)]
pub enum NbRead<'a> {
    /// One complete frame body; valid until the next call.
    Frame(&'a [u8]),
    /// The stream has no more bytes right now; re-poll on readiness.
    WouldBlock,
    /// Clean EOF at a frame boundary.
    Closed,
}

/// Incremental frame reader for nonblocking streams: accumulates the
/// 8-byte length prefix and then the body across however many partial
/// reads the kernel delivers, yielding one frame at a time. The body
/// buffer is reused across frames (grows to the largest frame seen), so a
/// warm accumulator allocates nothing — the event-loop counterpart of
/// [`FrameReader`].
pub struct FrameAccumulator {
    head: [u8; 8],
    head_len: usize,
    body: Vec<u8>,
    /// Bytes of `body` filled so far; `body.len()` is the target once the
    /// header is complete.
    filled: usize,
    /// Header fully parsed and validated for the in-progress frame.
    have_len: bool,
}

impl FrameAccumulator {
    pub fn new() -> FrameAccumulator {
        FrameAccumulator { head: [0u8; 8], head_len: 0, body: Vec::new(), filled: 0, have_len: false }
    }

    /// Advance by at most one frame. EOF in the middle of a frame is an
    /// `UnexpectedEof` error; EOF between frames is `Closed`. After
    /// `Frame` is returned the caller must process the body before the
    /// next call (the buffer is reused).
    pub fn poll_frame<'a>(&'a mut self, r: &mut impl Read) -> Result<NbRead<'a>, FrameError> {
        // Phase 1: accumulate the length prefix.
        while !self.have_len {
            match r.read(&mut self.head[self.head_len..]) {
                Ok(0) => {
                    if self.head_len == 0 {
                        return Ok(NbRead::Closed);
                    }
                    return Err(FrameError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "truncated frame length",
                    )));
                }
                Ok(n) => {
                    self.head_len += n;
                    if self.head_len == 8 {
                        let len = u64::from_le_bytes(self.head);
                        if len > MAX_FRAME_LEN {
                            return Err(FrameError::TooLarge(len));
                        }
                        self.body.clear();
                        self.body.resize(len as usize, 0);
                        self.filled = 0;
                        self.have_len = true;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(NbRead::WouldBlock)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
        // Phase 2: accumulate the body.
        while self.filled < self.body.len() {
            match r.read(&mut self.body[self.filled..]) {
                Ok(0) => {
                    return Err(FrameError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "truncated frame body",
                    )))
                }
                Ok(n) => self.filled += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return Ok(NbRead::WouldBlock)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
        // Frame complete: reset header state for the next one, hand the
        // body out borrowed.
        self.head_len = 0;
        self.have_len = false;
        Ok(NbRead::Frame(&self.body))
    }
}

impl Default for FrameAccumulator {
    fn default() -> Self {
        FrameAccumulator::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &vec![0xAB; 100_000]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap(), vec![0xAB; 100_000]);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn oversized_length_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        buf.extend_from_slice(b"xx");
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::TooLarge(_))));
    }

    #[test]
    fn truncated_prefix_is_io_error() {
        let mut r = Cursor::new(vec![1u8, 2, 3]); // 3 of 8 prefix bytes
        assert!(matches!(read_frame(&mut r), Err(FrameError::Io(_))));
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u64.to_le_bytes());
        buf.extend_from_slice(b"only5");
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Io(_))));
    }

    #[test]
    fn frame_writer_reader_roundtrip_msgs() {
        use crate::protocol::{decode_msg, Msg, RunId, TaskFinishedInfo};
        use crate::taskgraph::TaskId;
        let msgs = [
            Msg::Heartbeat,
            Msg::StealRequest { run: RunId(1), task: TaskId(5) },
            Msg::TaskFinished(TaskFinishedInfo {
                run: RunId(2),
                task: TaskId(9),
                nbytes: 27,
                duration_us: 6,
            }),
        ];
        let mut wire = Vec::new();
        let mut fw = FrameWriter::new();
        for m in &msgs {
            fw.send(&mut wire, m).unwrap();
        }
        let mut r = Cursor::new(wire);
        let mut fr = FrameReader::new();
        for m in &msgs {
            let bytes = fr.read(&mut r).unwrap();
            assert_eq!(&decode_msg(bytes).unwrap(), m);
        }
        assert!(matches!(fr.read(&mut r), Err(FrameError::Closed)));
    }

    /// Yields one byte per read, interleaving `WouldBlock` between every
    /// byte — the worst-case partial-read schedule a nonblocking socket
    /// can produce.
    struct Dribble {
        data: Vec<u8>,
        pos: usize,
        ready: bool,
    }

    impl Read for Dribble {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if !self.ready {
                self.ready = true;
                return Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
            }
            self.ready = false;
            if self.pos == self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn accumulator_reassembles_across_partial_reads() {
        use crate::protocol::{decode_msg, Msg, RunId};
        use crate::taskgraph::TaskId;
        let msgs: Vec<Msg> =
            (0..3).map(|i| Msg::StealRequest { run: RunId(2), task: TaskId(i) }).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            append_frame(&mut wire, m).unwrap();
        }
        let mut r = Dribble { data: wire, pos: 0, ready: false };
        let mut acc = FrameAccumulator::new();
        let mut got = Vec::new();
        loop {
            match acc.poll_frame(&mut r).unwrap() {
                NbRead::Frame(bytes) => got.push(decode_msg(bytes).unwrap()),
                NbRead::WouldBlock => continue, // dribble: re-poll
                NbRead::Closed => break,
            }
        }
        assert_eq!(got, msgs);
    }

    #[test]
    fn accumulator_eof_mid_frame_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u64.to_le_bytes());
        buf.extend_from_slice(b"only5");
        let mut acc = FrameAccumulator::new();
        let mut r = Cursor::new(buf);
        assert!(matches!(acc.poll_frame(&mut r), Err(FrameError::Io(_))));
        // Mid-prefix truncation too.
        let mut acc = FrameAccumulator::new();
        let mut r = Cursor::new(vec![1u8, 2, 3]);
        assert!(matches!(acc.poll_frame(&mut r), Err(FrameError::Io(_))));
    }

    #[test]
    fn accumulator_rejects_oversized_before_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        let mut acc = FrameAccumulator::new();
        let mut r = Cursor::new(buf);
        assert!(matches!(acc.poll_frame(&mut r), Err(FrameError::TooLarge(_))));
    }

    #[test]
    fn append_frame_coalesces_batches() {
        use crate::protocol::{decode_msg, Msg, RunId};
        use crate::taskgraph::TaskId;
        // Several frames appended to one buffer are readable one by one —
        // the server's batched flush relies on this byte-compatibility.
        let msgs: Vec<Msg> = (0..5)
            .map(|i| Msg::StealRequest { run: RunId(1), task: TaskId(i) })
            .collect();
        let mut batch = Vec::new();
        for m in &msgs {
            append_frame(&mut batch, m).unwrap();
        }
        let mut r = Cursor::new(batch);
        for m in &msgs {
            assert_eq!(&decode_msg(&read_frame(&mut r).unwrap()).unwrap(), m);
        }
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }
}
