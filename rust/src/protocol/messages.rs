//! The typed message set — the Dask-like operations RSDS needs (§IV: "it
//! supports a minimum set of DASK message types which are necessary to run
//! the most common DASK workflows").

use crate::taskgraph::{TaskGraph, TaskId};

/// Error-string prefix a worker puts on a `task-erred` whose cause was a
/// failed *input fetch* (dead peer, stale `who_has` address) rather than
/// the task's own computation. The reactor treats such errors as
/// recoverable — it re-runs the task instead of failing the run — because
/// lineage recovery will re-send it with fresh input locations. A plain
/// string convention (not a message field) keeps the wire format stable.
pub const FETCH_FAILED_PREFIX: &str = "fetch-failed: ";

/// Substring the server puts in a `graph-failed` reason when a run died
/// because its worker-disconnect recovery budget ran out (as opposed to a
/// task error or an unknown scheduler). Clients opted into
/// [`crate::client::Client::with_retry_exhausted`] match on it to decide
/// that a resubmission is worthwhile: the cluster lost capacity, the graph
/// itself is fine. A string convention (not a message field) keeps the
/// wire format stable.
pub const RECOVERY_EXHAUSTED_REASON: &str = "recovery budget exhausted";

/// Server-assigned namespace for one submitted graph.
///
/// [`TaskId`]s are dense indices *within* one graph, so they recycle across
/// submissions; any state that outlives a single graph — worker queues and
/// data stores, steal bookkeeping, scheduler pools — must key by
/// `(RunId, TaskId)`. Every protocol message that names a task therefore
/// also names its run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RunId(pub u32);

impl std::fmt::Display for RunId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Where to fetch a task input from: the producing worker's data-serving
/// address (Dask's `who_has`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskInputLoc {
    pub task: TaskId,
    /// Peer address `host:port`; empty when the input is local.
    pub addr: String,
    pub nbytes: u64,
}

/// Completion report (worker → server).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskFinishedInfo {
    pub run: RunId,
    pub task: TaskId,
    pub nbytes: u64,
    /// Pure execution time measured by the worker, µs.
    pub duration_us: u64,
}

/// All protocol messages. One msgpack map on the wire, discriminated by
/// `"op"`.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    // ---- registration ----
    /// client → server
    RegisterClient { name: String },
    /// worker → server; `data_addr` is where peers fetch outputs from,
    /// `node` groups workers sharing a machine.
    RegisterWorker { name: String, ncores: u32, node: u32, data_addr: String },
    /// server → peer: registration accepted, your id is `id`.
    Welcome { id: u32 },

    // ---- graph lifecycle ----
    /// client → server: run this graph. `scheduler` optionally names the
    /// algorithm serving this run (`random` | `ws` | …); `None` uses the
    /// server's default. Latency-sensitive and throughput-oriented clients
    /// can thereby pick different schedulers on one shared server.
    SubmitGraph { graph: TaskGraph, scheduler: Option<String> },
    /// server → client: graph accepted; all later messages about it carry
    /// `run`. Clients may pipeline further submissions immediately. Also
    /// sent when a previously parked submission (see [`Msg::RunQueued`])
    /// is activated from the admission queue.
    GraphSubmitted { run: RunId, n_tasks: u64 },
    /// server → client: the submission was accepted but *parked* — the
    /// client is at its live-run cap, so the graph waits in the server's
    /// admission queue. `position` is the number of *this client's*
    /// submissions queued ahead of it at park time (activation is FIFO
    /// per client — other tenants' backlogs don't gate it). A
    /// `graph-submitted` for the same run follows when it activates;
    /// `wait()` spans the queued phase transparently.
    RunQueued { run: RunId, position: u64 },
    /// server → client: all tasks of `run` finished.
    GraphDone { run: RunId, makespan_us: u64, n_tasks: u64 },
    /// server → client: execution of `run` failed.
    GraphFailed { run: RunId, reason: String },
    /// server → worker: `run` retired (done or failed) — drop its queued
    /// tasks and stored outputs. Without this, a long-lived worker's
    /// `(run, task)`-keyed store would grow without bound across runs.
    ReleaseRun { run: RunId },

    // ---- task execution ----
    /// server → worker: execute a task. Inputs carry `who_has` addresses.
    ComputeTask {
        run: RunId,
        task: TaskId,
        key: String,
        /// Serialized payload spec (what to run).
        payload: crate::taskgraph::Payload,
        duration_us: u64,
        output_size: u64,
        inputs: Vec<TaskInputLoc>,
        priority: i64,
    },
    /// worker → server: task done, output stored locally.
    TaskFinished(TaskFinishedInfo),
    /// worker → server: task raised.
    TaskErred { run: RunId, task: TaskId, error: String },

    // ---- stealing (§IV-C retraction protocol) ----
    /// server → worker: try to give task back (not started yet?).
    StealRequest { run: RunId, task: TaskId },
    /// worker → server: `ok` iff the task was still queued and is now
    /// retracted; false if it already runs / finished.
    StealResponse { run: RunId, task: TaskId, ok: bool },

    // ---- recovery (lineage-based worker-disconnect recovery) ----
    /// server → worker: unconditionally drop the queued copy of this task
    /// (no response expected — unlike `steal-request` there is nothing to
    /// negotiate). Sent when an input of the task evaporated with a dead
    /// worker: the assignment's `who_has` addresses are stale, so the task
    /// will be re-sent after its inputs are recomputed. A task already
    /// running is left alone; its eventual `task-finished` is accepted as a
    /// (possibly duplicated) result, and its `task-erred` with a
    /// `fetch-failed:` error is treated as recoverable. See
    /// `docs/recovery.md`.
    CancelCompute { run: RunId, task: TaskId },

    // ---- data plane ----
    /// worker → worker: send me this task's output.
    FetchData { run: RunId, task: TaskId },
    /// worker → worker: the requested bytes.
    DataReply { run: RunId, task: TaskId, data: Vec<u8> },
    /// server → worker (zero-worker experiments): a client asks for data.
    FetchFromServer { run: RunId, task: TaskId },
    /// worker → server: requested data (zero worker replies with a small
    /// mocked constant object, §IV-D).
    DataToServer { run: RunId, task: TaskId, data: Vec<u8> },

    // ---- lifecycle ----
    /// server → all: shut down cleanly.
    Shutdown,
    /// liveness probe (either direction).
    Heartbeat,
}

impl Msg {
    /// Wire discriminant.
    pub fn op(&self) -> &'static str {
        match self {
            Msg::RegisterClient { .. } => "register-client",
            Msg::RegisterWorker { .. } => "register-worker",
            Msg::Welcome { .. } => "welcome",
            Msg::SubmitGraph { .. } => "submit-graph",
            Msg::GraphSubmitted { .. } => "graph-submitted",
            Msg::RunQueued { .. } => "run-queued",
            Msg::GraphDone { .. } => "graph-done",
            Msg::GraphFailed { .. } => "graph-failed",
            Msg::ReleaseRun { .. } => "release-run",
            Msg::ComputeTask { .. } => "compute-task",
            Msg::TaskFinished(..) => "task-finished",
            Msg::TaskErred { .. } => "task-erred",
            Msg::StealRequest { .. } => "steal-request",
            Msg::StealResponse { .. } => "steal-response",
            Msg::CancelCompute { .. } => "cancel-compute",
            Msg::FetchData { .. } => "fetch-data",
            Msg::DataReply { .. } => "data-reply",
            Msg::FetchFromServer { .. } => "fetch-from-server",
            Msg::DataToServer { .. } => "data-to-server",
            Msg::Shutdown => "shutdown",
            Msg::Heartbeat => "heartbeat",
        }
    }
}
