//! The typed message set — the Dask-like operations RSDS needs (§IV: "it
//! supports a minimum set of DASK message types which are necessary to run
//! the most common DASK workflows").

use crate::taskgraph::{TaskGraph, TaskId, TaskSpec};

/// Error-string prefix a worker puts on a `task-erred` whose cause was a
/// failed *input fetch* (dead peer, stale `who_has` address) rather than
/// the task's own computation. The reactor treats such errors as
/// recoverable — it re-runs the task instead of failing the run — because
/// lineage recovery will re-send it with fresh input locations. A plain
/// string convention (not a message field) keeps the wire format stable.
pub const FETCH_FAILED_PREFIX: &str = "fetch-failed: ";

/// Substring the server puts in a `graph-failed` reason when a run died
/// because its worker-disconnect recovery budget ran out (as opposed to a
/// task error or an unknown scheduler). Clients opted into
/// [`crate::client::Client::with_retry_exhausted`] match on it to decide
/// that a resubmission is worthwhile: the cluster lost capacity, the graph
/// itself is fine. A string convention (not a message field) keeps the
/// wire format stable.
pub const RECOVERY_EXHAUSTED_REASON: &str = "recovery budget exhausted";

/// Server-assigned namespace for one submitted graph.
///
/// [`TaskId`]s are dense indices *within* one graph, so they recycle across
/// submissions; any state that outlives a single graph — worker queues and
/// data stores, steal bookkeeping, scheduler pools — must key by
/// `(RunId, TaskId)`. Every protocol message that names a task therefore
/// also names its run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RunId(pub u32);

impl std::fmt::Display for RunId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// Protocol cap on alternate replica addresses per input. Matches
/// `ReplicaSet::INLINE` on the server: the primary address plus up to
/// three alternates covers k ≤ 3 replication without ever pushing the
/// borrowed decode ([`super::codec::TaskInputRef`]) onto the heap. Both
/// codecs truncate longer lists on decode.
pub const MAX_ALT_ADDRS: usize = 3;

/// Where to fetch a task input from: the producing worker's data-serving
/// address (Dask's `who_has`), plus any alternate replica addresses the
/// server knew of at emission — fetch failover walks these before falling
/// back to the `fetch-failed` retry path.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskInputLoc {
    pub task: TaskId,
    /// Peer address `host:port`; empty when the input is local.
    pub addr: String,
    /// Alternate replica addresses (never contains `addr`; at most
    /// [`MAX_ALT_ADDRS`]). Empty on the wire means "no replicas known" —
    /// pre-replication frames decode unchanged.
    pub alts: Vec<String>,
    pub nbytes: u64,
}

/// Completion report (worker → server).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskFinishedInfo {
    pub run: RunId,
    pub task: TaskId,
    pub nbytes: u64,
    /// Pure execution time measured by the worker, µs.
    pub duration_us: u64,
}

/// All protocol messages. One msgpack map on the wire, discriminated by
/// `"op"`.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    // ---- registration ----
    /// client → server
    RegisterClient { name: String },
    /// worker → server; `data_addr` is where peers fetch outputs from,
    /// `node` groups workers sharing a machine.
    RegisterWorker { name: String, ncores: u32, node: u32, data_addr: String },
    /// server → peer: registration accepted, your id is `id`.
    Welcome { id: u32 },

    // ---- graph lifecycle ----
    /// client → server: run this graph. `scheduler` optionally names the
    /// algorithm serving this run (`random` | `ws` | …); `None` uses the
    /// server's default. Latency-sensitive and throughput-oriented clients
    /// can thereby pick different schedulers on one shared server.
    /// `open: true` declares the run *extensible*: the client may stream
    /// further tasks with [`Msg::SubmitExtend`], and the run stays live
    /// (even fully quiescent) until a closing extension arrives. `false`
    /// (absent on the wire) is the classic one-shot submission.
    SubmitGraph { graph: TaskGraph, scheduler: Option<String>, open: bool },
    /// client → server: append a batch of tasks to an *open* live run
    /// (incremental graph construction). Task ids continue the run's dense
    /// id space; inputs may reference any earlier task, including already
    /// finished ones. `last: true` closes the run — once the close lands
    /// the run retires as soon as every task has finished. An empty batch
    /// with `last: true` is a pure close. Acked with `graph-submitted`
    /// carrying the new task total; an extension of an unknown/retired run
    /// answers `graph-failed`.
    SubmitExtend { run: RunId, tasks: Vec<TaskSpec>, last: bool },
    /// server → client: graph accepted; all later messages about it carry
    /// `run`. Clients may pipeline further submissions immediately. Also
    /// sent when a previously parked submission (see [`Msg::RunQueued`])
    /// is activated from the admission queue.
    GraphSubmitted { run: RunId, n_tasks: u64 },
    /// server → client: the submission was accepted but *parked* — the
    /// client is at its live-run cap, so the graph waits in the server's
    /// admission queue. `position` is the number of *this client's*
    /// submissions queued ahead of it at park time (activation is FIFO
    /// per client — other tenants' backlogs don't gate it). A
    /// `graph-submitted` for the same run follows when it activates;
    /// `wait()` spans the queued phase transparently.
    RunQueued { run: RunId, position: u64 },
    /// server → client: all tasks of `run` finished.
    GraphDone { run: RunId, makespan_us: u64, n_tasks: u64 },
    /// server → client: execution of `run` failed.
    GraphFailed { run: RunId, reason: String },
    /// server → worker: `run` retired (done or failed) — drop its queued
    /// tasks and stored outputs. Without this, a long-lived worker's
    /// `(run, task)`-keyed store would grow without bound across runs.
    ReleaseRun { run: RunId },

    // ---- task execution ----
    /// server → worker: execute a task. Inputs carry `who_has` addresses.
    ComputeTask {
        run: RunId,
        task: TaskId,
        key: String,
        /// Serialized payload spec (what to run).
        payload: crate::taskgraph::Payload,
        duration_us: u64,
        output_size: u64,
        inputs: Vec<TaskInputLoc>,
        priority: i64,
        /// Graph-wide consumer count of this task's output — the worker
        /// store's initial reference count. `0` (absent on the wire) means
        /// "pin until `release-run`": sink outputs must survive for the
        /// client, and pre-replication frames decode to the safe default.
        consumers: u32,
        /// Core slots the task occupies on the worker. `1` (absent on the
        /// wire) is the ordinary single-slot task; pre-resource frames
        /// decode unchanged.
        cores: u32,
    },
    /// server → worker: raise a stored output's reference count by
    /// `consumers` — a graph extension added consumers of an output whose
    /// `compute-task` baked in a smaller count (or whose count already
    /// drained to its pinned/evicted end state). A worker that no longer
    /// holds the key ignores the message: the server only pins outputs it
    /// believes resident, and the `fetch-failed` resurrection path
    /// backstops a copy that evaporated in flight.
    PinData { run: RunId, task: TaskId, consumers: u32 },
    /// worker → server: task done, output stored locally.
    TaskFinished(TaskFinishedInfo),
    /// worker → server: task raised.
    TaskErred { run: RunId, task: TaskId, error: String },

    // ---- stealing (§IV-C retraction protocol) ----
    /// server → worker: try to give task back (not started yet?).
    StealRequest { run: RunId, task: TaskId },
    /// worker → server: `ok` iff the task was still queued and is now
    /// retracted; false if it already runs / finished.
    StealResponse { run: RunId, task: TaskId, ok: bool },

    // ---- recovery (lineage-based worker-disconnect recovery) ----
    /// server → worker: unconditionally drop the queued copy of this task
    /// (no response expected — unlike `steal-request` there is nothing to
    /// negotiate). Sent when an input of the task evaporated with a dead
    /// worker: the assignment's `who_has` addresses are stale, so the task
    /// will be re-sent after its inputs are recomputed. A task already
    /// running is left alone; its eventual `task-finished` is accepted as a
    /// (possibly duplicated) result, and its `task-erred` with a
    /// `fetch-failed:` error is treated as recoverable. See
    /// `docs/recovery.md`.
    CancelCompute { run: RunId, task: TaskId },

    // ---- replication (proactive k-replication of hot outputs) ----
    /// server → worker (the producer): push a copy of this output to each
    /// of `addrs` — peer *data* addresses, the k−1 replication targets the
    /// reactor chose. Fire-and-forget from the server's side; each
    /// receiving peer confirms with [`Msg::ReplicaAdded`].
    ReplicateData { run: RunId, task: TaskId, addrs: Vec<String> },
    /// worker → worker (data plane): unsolicited replica push — store
    /// these bytes pinned (replicas never self-evict; `release-run` and
    /// the spill tier manage them).
    PutData { run: RunId, task: TaskId, data: Vec<u8> },
    /// worker → server: I now hold a replica of this output (sent by the
    /// *receiving* peer of a [`Msg::PutData`]); the server appends the
    /// sender to `who_has` so fetches and recovery see the copy.
    ReplicaAdded { run: RunId, task: TaskId },
    /// worker → server: I dropped my copy (reference count hit zero — all
    /// consumers fetched it). The server prunes `who_has` so recovery
    /// never counts on evicted bytes.
    ReplicaDropped { run: RunId, task: TaskId },

    // ---- data plane ----
    /// worker → worker: send me this task's output.
    FetchData { run: RunId, task: TaskId },
    /// worker → worker: send me these tasks' outputs, coalesced. The
    /// serving peer answers with one ordinary [`Msg::DataReply`] frame
    /// per requested task, **in request order**, streamed back-to-back
    /// on the same connection. There is no batched reply frame: keeping
    /// replies as individual `data-reply` frames lets the server encode
    /// each one zero-copy straight from its store and lets the client
    /// start consuming the first object while later ones are still in
    /// flight. A peer that cannot produce one of the requested objects
    /// (even after its local grace period) closes the connection, which
    /// the requester treats as a recoverable fetch failure.
    FetchDataMany { run: RunId, tasks: Vec<TaskId> },
    /// worker → worker: the requested bytes.
    DataReply { run: RunId, task: TaskId, data: Vec<u8> },
    /// server → worker (zero-worker experiments): a client asks for data.
    FetchFromServer { run: RunId, task: TaskId },
    /// worker → server: requested data (zero worker replies with a small
    /// mocked constant object, §IV-D).
    DataToServer { run: RunId, task: TaskId, data: Vec<u8> },

    // ---- lifecycle ----
    /// server → all: shut down cleanly.
    Shutdown,
    /// liveness probe (either direction).
    Heartbeat,
}

impl Msg {
    /// Wire discriminant.
    pub fn op(&self) -> &'static str {
        match self {
            Msg::RegisterClient { .. } => "register-client",
            Msg::RegisterWorker { .. } => "register-worker",
            Msg::Welcome { .. } => "welcome",
            Msg::SubmitGraph { .. } => "submit-graph",
            Msg::SubmitExtend { .. } => "submit-extend",
            Msg::GraphSubmitted { .. } => "graph-submitted",
            Msg::RunQueued { .. } => "run-queued",
            Msg::GraphDone { .. } => "graph-done",
            Msg::GraphFailed { .. } => "graph-failed",
            Msg::ReleaseRun { .. } => "release-run",
            Msg::ComputeTask { .. } => "compute-task",
            Msg::PinData { .. } => "pin-data",
            Msg::TaskFinished(..) => "task-finished",
            Msg::TaskErred { .. } => "task-erred",
            Msg::StealRequest { .. } => "steal-request",
            Msg::StealResponse { .. } => "steal-response",
            Msg::CancelCompute { .. } => "cancel-compute",
            Msg::ReplicateData { .. } => "replicate-data",
            Msg::PutData { .. } => "put-data",
            Msg::ReplicaAdded { .. } => "replica-added",
            Msg::ReplicaDropped { .. } => "replica-dropped",
            Msg::FetchData { .. } => "fetch-data",
            Msg::FetchDataMany { .. } => "fetch-data-many",
            Msg::DataReply { .. } => "data-reply",
            Msg::FetchFromServer { .. } => "fetch-from-server",
            Msg::DataToServer { .. } => "data-to-server",
            Msg::Shutdown => "shutdown",
            Msg::Heartbeat => "heartbeat",
        }
    }
}
