//! End-to-end integration over real TCP: server + workers + client on
//! localhost, exercising the full protocol (registration, submission,
//! assignment, w2w data fetches, steal retraction, completion), the zero
//! worker, the Dask-emulation mode, and failure injection.

// Real-TCP timing suites are meaningless under the model-checking build;
// `tests/loom_models.rs` is the `--cfg loom` counterpart.
#![cfg(not(loom))]

use rsds::client::Client;
use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::protocol::{encode_msg, read_frame, write_frame, Msg};
use rsds::server::{serve, ServerConfig};
use rsds::worker::zero::run_zero_worker;
use rsds::worker::{run_worker, WorkerConfig, WorkerHandle};
use std::net::TcpStream;

fn server(scheduler: &str) -> rsds::server::ServerHandle {
    serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: scheduler.into(),
        seed: 42,
        profile: RuntimeProfile::rust(),
        emulate: false,
        ..ServerConfig::default()
    })
    .expect("server start")
}

fn workers(addr: &str, n: u32) -> Vec<WorkerHandle> {
    (0..n)
        .map(|i| {
            run_worker(WorkerConfig {
                server_addr: addr.to_string(),
                name: format!("it-w{i}"),
                ncores: 1,
                node: i / 4,
                memory_limit: None,
                data_plane: Default::default(),
            })
            .expect("worker start")
        })
        .collect()
}

#[test]
fn merge_graph_over_tcp_ws() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 4);
    let mut client = Client::connect(&addr, "it-client").unwrap();
    let g = graphgen::merge(300);
    let res = client.run_graph(&g).unwrap();
    assert_eq!(res.n_tasks, 301);
    assert!(res.makespan_us > 0);
    let reports = srv.reports();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].n_tasks, 301);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn tree_reduction_with_data_plane_random() {
    // tree forces w2w transfers under random placement; output correctness
    // is implied by completion (merge payloads consume real input bytes).
    let srv = server("random");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 3);
    let mut client = Client::connect(&addr, "it-client").unwrap();
    let res = client.run_graph(&graphgen::tree(7)).unwrap();
    assert_eq!(res.n_tasks, 127);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn sequential_graphs_same_cluster() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut client = Client::connect(&addr, "it-client").unwrap();
    let a = client.run_graph(&graphgen::merge(50)).unwrap();
    let b = client.run_graph(&graphgen::tree(5)).unwrap();
    let c = client.run_graph(&graphgen::wordbag(100, 10)).unwrap();
    assert_eq!(a.n_tasks, 51);
    assert_eq!(b.n_tasks, 31);
    assert_eq!(c.n_tasks, 50);
    assert_eq!(srv.reports().len(), 3);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn concurrent_clients_over_tcp() {
    // Multiple clients submit different graphs at the same time; the
    // multi-graph server interleaves them on one worker pool and reports
    // each run to the right client.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 4);
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("cc{i}")).unwrap();
                let g = if i % 2 == 0 { graphgen::merge(150) } else { graphgen::tree(6) };
                c.run_graph(&g).unwrap()
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, res) in results.iter().enumerate() {
        let want = if i % 2 == 0 { 151 } else { 63 };
        assert_eq!(res.n_tasks, want, "client {i}");
    }
    // Four distinct runs, four reports.
    let runs: std::collections::HashSet<_> = results.iter().map(|r| r.run).collect();
    assert_eq!(runs.len(), 4);
    assert_eq!(srv.reports().len(), 4);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn pipelined_submissions_single_client() {
    // One client pipelines three graphs and collects them out of order.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut c = Client::connect(&addr, "pipeline").unwrap();
    let r1 = c.submit(&graphgen::merge(40)).unwrap();
    let r2 = c.submit(&graphgen::tree(5)).unwrap();
    let r3 = c.submit(&graphgen::merge(60)).unwrap();
    assert_eq!(c.in_flight(), 3);
    let b = c.wait(r2).unwrap();
    let a = c.wait(r1).unwrap();
    let d = c.wait(r3).unwrap();
    assert_eq!((a.n_tasks, b.n_tasks, d.n_tasks), (41, 31, 61));
    assert_eq!(c.in_flight(), 0);
    assert_eq!(srv.reports().len(), 3);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn per_run_scheduler_choice_over_tcp() {
    // One server (default ws); concurrent clients pick different
    // schedulers per submission and both complete on the shared pool.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 3);
    let handles: Vec<_> = ["random", "ws"]
        .into_iter()
        .map(|sched| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("sched-{sched}")).unwrap();
                c.run_graph_with(&graphgen::merge(120), Some(sched)).unwrap()
            })
        })
        .collect();
    for h in handles {
        let res = h.join().unwrap();
        assert_eq!(res.n_tasks, 121);
    }
    assert_eq!(srv.report_count(), 2);
    // Unknown scheduler: the submission is acked, then fails — only that
    // run, the connection and server stay usable.
    let mut c = Client::connect(&addr, "sched-bogus").unwrap();
    let err = c.run_graph_with(&graphgen::merge(10), Some("fifo")).unwrap_err();
    assert!(format!("{err:#}").contains("unknown scheduler"), "{err:#}");
    let ok = c.run_graph(&graphgen::merge(10)).unwrap();
    assert_eq!(ok.n_tasks, 11);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn reports_since_watermark_returns_only_new_reports() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut client = Client::connect(&addr, "wm").unwrap();
    let mut watermark = 0;
    for i in 0..3u64 {
        client.run_graph(&graphgen::merge(20 + i as usize)).unwrap();
        let (fresh, next) = srv.reports_since(watermark);
        assert_eq!(fresh.len(), 1, "exactly the new report at step {i}");
        assert_eq!(fresh[0].n_tasks, 21 + i);
        assert_eq!(next, watermark + 1);
        watermark = next;
    }
    assert_eq!(srv.report_count(), 3);
    assert_eq!(srv.reports_since(watermark).0.len(), 0);
    let (past_end, wm) = srv.reports_since(999);
    assert_eq!(past_end.len(), 0, "past-the-end watermark is empty");
    assert_eq!(wm, 999, "watermarks never go backwards");
    // Full history still available from zero.
    assert_eq!(srv.reports().len(), 3);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn shutdown_joins_connection_threads() {
    // Regression for leaked per-connection reader/writer threads: shutdown
    // must join them all, with live clients and workers still attached (a
    // hang here fails the test by timeout).
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut client = Client::connect(&addr, "joiner").unwrap();
    assert_eq!(client.run_graph(&graphgen::merge(30)).unwrap().n_tasks, 31);
    // Extra idle connections that never register.
    let idle: Vec<TcpStream> = (0..3).map(|_| TcpStream::connect(&addr).unwrap()).collect();
    srv.shutdown();
    drop(idle);
    for w in &ws {
        w.shutdown();
    }
}

#[test]
fn zero_worker_runs_graphs_instantly() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let zws: Vec<_> = (0..4)
        .map(|i| {
            run_zero_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("zero-{i}"),
                ncores: 1,
                node: 0,
                memory_limit: None,
                data_plane: Default::default(),
            })
            .unwrap()
        })
        .collect();
    let mut client = Client::connect(&addr, "it-client").unwrap();
    // merge_slow with 100 ms tasks: a real worker would need ~50 s; the
    // zero worker must finish in far under a second of task time.
    let g = graphgen::merge_slow(2_000, 100_000);
    let res = client.run_graph(&g).unwrap();
    assert_eq!(res.n_tasks, 2_001);
    let aot = res.makespan_us as f64 / res.n_tasks as f64;
    assert!(
        aot < 2_000.0,
        "zero-worker AOT should be far below task duration: {aot} µs/task"
    );
    for z in &zws {
        z.shutdown();
    }
    srv.shutdown();
}

#[test]
fn dask_emulation_is_measurably_slower() {
    let run = |emulate: bool| {
        let srv = serve(ServerConfig {
            addr: "127.0.0.1:0".into(),
            scheduler: if emulate { "dask-ws".into() } else { "ws".into() },
            seed: 1,
            profile: if emulate { RuntimeProfile::python() } else { RuntimeProfile::rust() },
            emulate,
            ..ServerConfig::default()
        })
        .unwrap();
        let addr = srv.addr.to_string();
        let zws: Vec<_> = (0..4)
            .map(|i| {
                run_zero_worker(WorkerConfig {
                    server_addr: addr.clone(),
                    name: format!("z{i}"),
                    ncores: 1,
                    node: 0,
                    memory_limit: None,
                    data_plane: Default::default(),
                })
                .unwrap()
            })
            .collect();
        let mut client = Client::connect(&addr, "c").unwrap();
        let res = client.run_graph(&graphgen::merge(1_000)).unwrap();
        for z in &zws {
            z.shutdown();
        }
        srv.shutdown();
        res.makespan_us as f64
    };
    let rsds = run(false);
    let dask = run(true);
    assert!(
        dask > rsds * 2.0,
        "python emulation should dominate: rsds {rsds} µs vs dask {dask} µs"
    );
}

#[test]
fn hlo_payload_graph_end_to_end() {
    // xarray graph executes the Pallas-compiled artifacts on real workers.
    if !rsds::runtime::Runtime::artifacts_present(&rsds::runtime::Runtime::default_dir()) {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 4);
    let mut client = Client::connect(&addr, "it-client").unwrap();
    let g = graphgen::xarray(25);
    assert!(g.needs_runtime());
    let res = client.run_graph(&g).unwrap();
    assert_eq!(res.n_tasks, g.len() as u64);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn worker_killed_mid_run_recovers_and_completes() {
    // PR 3 acceptance: kill 1 of 3 workers while the graph is mid-flight.
    // The run must NOT fail — the server absorbs the disconnect by lineage
    // recovery and the client gets the same result a clean run produces.
    let g = graphgen::merge_slow(60, 100_000); // ~6 s of work on 3 cores
    let clean = {
        let srv = server("ws");
        let addr = srv.addr.to_string();
        let ws = workers(&addr, 3);
        let mut client = Client::connect(&addr, "clean").unwrap();
        let res = client.run_graph(&g).unwrap();
        for w in &ws {
            w.shutdown();
        }
        srv.shutdown();
        res
    };
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let mut client = Client::connect(&addr, "killer").unwrap();
    // ~6 s of work ahead; the kill at 400 ms lands well inside the run,
    // with assignments queued (and likely some outputs stored) on the
    // victim.
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(400));
        victim.shutdown();
    });
    let res = client.run_graph(&g).expect("run must survive the worker death");
    killer.join().unwrap();
    assert_eq!(res.n_tasks, clean.n_tasks, "same result as the no-kill run");
    let reports = srv.reports();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].n_tasks, 61);
    assert!(reports[0].recoveries >= 1, "server recorded the recovery");
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

// ---- run-fair dispatch + admission control (PR 4 tentpole) ----

fn server_with_cap(cap: usize) -> rsds::server::ServerHandle {
    serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        max_live_runs_per_client: cap,
        ..ServerConfig::default()
    })
    .expect("server start")
}

#[test]
fn admission_cap_queues_and_completes_over_tcp() {
    // Cap 1: a pipelining client's second and third submissions park in
    // the admission queue (acked with run-queued), then activate FIFO as
    // runs retire; wait() spans the queued phase transparently.
    let srv = server_with_cap(1);
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut c = Client::connect(&addr, "queued").unwrap();
    // ~3 s of work on 2 cores keeps run 1 busy while 2 and 3 are parked.
    let r1 = c.submit(&graphgen::merge_slow(60, 100_000)).unwrap();
    let r2 = c.submit(&graphgen::merge(30)).unwrap();
    let r3 = c.submit(&graphgen::merge(40)).unwrap();
    assert!(!c.is_queued(r1), "first run executes immediately");
    assert!(c.is_queued(r2), "second run is parked (cap 1)");
    assert!(c.is_queued(r3), "third run is parked (cap 1)");
    assert_eq!(c.in_flight(), 3);
    let b = c.wait(r2).unwrap();
    assert!(!c.is_queued(r2), "completed run is not queued");
    let a = c.wait(r1).unwrap();
    let d = c.wait(r3).unwrap();
    assert_eq!((a.n_tasks, b.n_tasks, d.n_tasks), (61, 31, 41));
    // FIFO activation under cap 1 ⇒ completion order r1, r2, r3.
    let reports = srv.reports();
    let order: Vec<_> = reports.iter().map(|rep| rep.run).collect();
    assert_eq!(order, vec![r1, r2, r3]);
    // Queue wait is part of the parked runs' makespan (client latency).
    assert!(
        reports[1].makespan_us >= reports[0].makespan_us / 2,
        "parked run's makespan includes its queued phase: {} vs {}",
        reports[1].makespan_us,
        reports[0].makespan_us
    );
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn worker_killed_while_runs_parked_recovers_and_activates() {
    // Fairness × recovery over real TCP: kill a worker while a run sits in
    // the admission queue. The live run recovers; the parked run activates
    // on the shrunken cluster and completes.
    let srv = server_with_cap(1);
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let mut c = Client::connect(&addr, "park-kill").unwrap();
    let r1 = c.submit(&graphgen::merge_slow(40, 100_000)).unwrap(); // ~2 s / 3 cores
    let r2 = c.submit(&graphgen::merge(50)).unwrap();
    assert!(c.is_queued(r2));
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(300));
        victim.shutdown();
    });
    let a = c.wait(r1).expect("live run survives the worker death");
    let b = c.wait(r2).expect("parked run activates and completes");
    killer.join().unwrap();
    assert_eq!(a.n_tasks, 41);
    assert_eq!(b.n_tasks, 51);
    let reports = srv.reports();
    assert_eq!(reports.len(), 2);
    assert!(
        reports.iter().any(|rep| rep.recoveries >= 1),
        "the in-flight run recorded its recovery: {reports:?}"
    );
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn client_retries_budget_exhausted_run_over_tcp() {
    // PR 5 satellite: with the server's recovery budget at 0, a worker
    // death mid-run fails the run ("recovery budget exhausted"). A client
    // opted into with_retry_exhausted resubmits transparently and the
    // retry completes on the survivors — run_graph returns success under
    // the original call.
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        max_recoveries: 0,
        ..ServerConfig::default()
    })
    .expect("server start");
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let mut client = Client::connect(&addr, "retrier").unwrap().with_retry_exhausted(2);
    // ~6 s of work on 3 cores; the kill at 400 ms lands well inside the
    // run with assignments (and likely outputs) on the victim, so the
    // zero-budget recovery fails the first attempt.
    let g = graphgen::merge_slow(60, 100_000);
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(400));
        victim.shutdown();
    });
    let res = client.run_graph(&g).expect("retry must rescue the run");
    killer.join().unwrap();
    assert_eq!(res.n_tasks, 61);
    assert_eq!(client.retries_used(), 1, "exactly one resubmission");
    // Only the successful attempt produces a report (failed runs never
    // complete), and it ran entirely on the two survivors.
    let reports = srv.reports();
    assert_eq!(reports.len(), 1, "{reports:?}");
    assert_eq!(reports[0].n_tasks, 61);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn retry_disabled_surfaces_exhausted_failure() {
    // Without the opt-in, the same scenario surfaces the failure to the
    // caller (the pre-PR5 behavior, now under budget 0).
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        max_recoveries: 0,
        ..ServerConfig::default()
    })
    .expect("server start");
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let mut client = Client::connect(&addr, "no-retry").unwrap();
    let g = graphgen::merge_slow(60, 100_000);
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(400));
        victim.shutdown();
    });
    let err = client.run_graph(&g).expect_err("budget 0 must fail the run");
    killer.join().unwrap();
    assert!(
        err.to_string().contains("recovery budget exhausted"),
        "unexpected failure: {err}"
    );
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn report_retention_bounds_server_history() {
    // Regression: long-lived servers must not grow report history without
    // bound. With retention 2, five runs leave a 2-report window while
    // report_count and reports_since watermarks stay monotonic.
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        report_retention: 2,
        ..ServerConfig::default()
    })
    .expect("server start");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut c = Client::connect(&addr, "retention").unwrap();
    for i in 0..5usize {
        c.run_graph(&graphgen::merge(10 + i)).unwrap();
    }
    assert_eq!(srv.report_count(), 5, "monotonic completion count");
    let window = srv.reports();
    assert_eq!(window.len(), 2, "history bounded by retention");
    assert_eq!(window[0].n_tasks, 14, "window holds the newest reports");
    assert_eq!(window[1].n_tasks, 15);
    // Watermark semantics across eviction: a lagging poller gets the
    // retained suffix and a watermark that absorbs the evicted gap, so
    // the next poll yields nothing instead of re-delivering the tail.
    let (lagged, next) = srv.reports_since(0);
    assert_eq!(lagged.len(), 2, "only the retained window is deliverable");
    assert_eq!(next, 5, "watermark jumps over the evicted gap");
    assert_eq!(srv.reports_since(next).0.len(), 0, "no duplicate re-delivery");
    assert_eq!(srv.reports_since(4).0.len(), 1);
    assert_eq!(srv.reports_since(5).0.len(), 0);
    assert_eq!(srv.reports_since(999).0.len(), 0);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn fairness_policy_configurable_over_tcp() {
    // A server on the weighted policy still serves concurrent clients
    // correctly (the latency ordering itself is benched by fig_fairness).
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        fairness: "weighted".into(),
        ..ServerConfig::default()
    })
    .expect("server start");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 3);
    let handles: Vec<_> = (0..3)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("fair{i}")).unwrap();
                c.run_graph(&graphgen::merge(100 + i * 40)).unwrap()
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let res = h.join().unwrap();
        assert_eq!(res.n_tasks, 101 + i as u64 * 40);
    }
    assert_eq!(srv.report_count(), 3);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn malformed_frame_disconnects_not_crashes() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    // Raw garbage bytes in a valid frame: server must drop the conn.
    let mut s = TcpStream::connect(&addr).unwrap();
    write_frame(&mut s, &[0xc1, 0xff, 0x00]).unwrap(); // 0xc1 = reserved
    // Connection should be closed by the server.
    let got = read_frame(&mut s);
    assert!(got.is_err(), "server must close on malformed input");
    // Server still serves normal clients afterwards.
    let ws = workers(&addr, 1);
    let mut client = Client::connect(&addr, "after-garbage").unwrap();
    let res = client.run_graph(&graphgen::merge(10)).unwrap();
    assert_eq!(res.n_tasks, 11);
    ws[0].shutdown();
    srv.shutdown();
}

#[test]
fn oversized_frame_rejected() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let mut s = TcpStream::connect(&addr).unwrap();
    // Claim an 8 GiB frame; the server must refuse without allocating.
    let len: u64 = 8 << 30;
    use std::io::Write;
    s.write_all(&len.to_le_bytes()).unwrap();
    s.write_all(b"xxxx").unwrap();
    let got = read_frame(&mut s);
    assert!(got.is_err());
    srv.shutdown();
}

// ---- sharded control plane (PR 7 tentpole) ----

fn server_sharded(shards: usize) -> rsds::server::ServerHandle {
    serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        shards,
        ..ServerConfig::default()
    })
    .expect("server start")
}

#[test]
fn concurrent_clients_on_four_shards() {
    // Eight clients hash across four reactor shards; the workers each home
    // on one shard and serve runs owned by all of them, so every graph
    // exercises the cross-shard Forward path both ways (compute out,
    // task-finished back). Nightly TSan runs this test to race-check the
    // shard channels and the shared report store.
    let srv = server_sharded(4);
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 4);
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("sh{i}")).unwrap();
                let g = if i % 2 == 0 { graphgen::merge(120) } else { graphgen::tree(6) };
                c.run_graph(&g).unwrap()
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, res) in results.iter().enumerate() {
        let want = if i % 2 == 0 { 121 } else { 63 };
        assert_eq!(res.n_tasks, want, "client {i}");
    }
    // Eight distinct runs: the strided per-shard RunId allocation must not
    // collide across shards; all land in the one shared report store.
    let runs: std::collections::HashSet<_> = results.iter().map(|r| r.run).collect();
    assert_eq!(runs.len(), 8);
    assert_eq!(srv.report_count(), 8);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn worker_killed_mid_run_recovers_on_sharded_server() {
    // Cross-shard death broadcast: the victim homes on exactly one shard
    // while clients hashed to *other* shards hold live runs with
    // assignments on it. The home shard must broadcast WorkerDead, every
    // owning shard must recover its own runs exactly once, and any Forward
    // racing the death must be dropped, not delivered to the corpse —
    // observable as: all four runs complete with clean results.
    let srv = server_sharded(4);
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(400));
        victim.shutdown();
    });
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("shk{i}")).unwrap();
                // ~2 s of task time per run keeps assignments in flight on
                // the victim when the kill lands at 400 ms.
                c.run_graph(&graphgen::merge_slow(20, 100_000))
                    .expect("run must survive the cross-shard worker death")
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    killer.join().unwrap();
    for res in &results {
        assert_eq!(res.n_tasks, 21);
    }
    let reports = srv.reports();
    assert_eq!(reports.len(), 4);
    assert!(
        reports.iter().any(|rep| rep.recoveries >= 1),
        "at least one run recorded the recovery: {reports:?}"
    );
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

// ---- replicated object store (PR 8 tentpole) ----

fn server_replicated(k: usize) -> rsds::server::ServerHandle {
    serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 42,
        replication: k,
        // Every output with at least one consumer is "hot": the whole
        // graph replicates, so the kill tests don't depend on which tasks
        // the hint heuristic happens to pick.
        replication_fanout: 1,
        ..ServerConfig::default()
    })
    .expect("server start")
}

/// One long busy root + `n_leaves` fast leaves + a sink over all of them.
/// The leaves finish (and replicate) within the first few hundred ms while
/// the root pins exactly one worker for `root_us`, so the cluster reaches a
/// quiescent "one busy, the rest idle holding data" phase — the window the
/// kill tests aim at: an idle worker's death is pure data loss, with zero
/// assignments in flight on it.
fn stem_graph(n_leaves: usize, root_us: u64) -> rsds::taskgraph::TaskGraph {
    use rsds::taskgraph::{GraphBuilder, Payload};
    let mut b = GraphBuilder::new();
    let root = b.add("root", vec![], root_us, 1_000, Payload::BusyWait);
    let mut inputs = vec![root];
    for i in 0..n_leaves {
        inputs.push(b.add(format!("leaf-{i}"), vec![], 1_000, 10_000, Payload::NoOp));
    }
    b.add("sink", inputs, 1_000, 100, Payload::MergeInputs);
    b.build("stem").expect("valid graph")
}

/// Wait for the stem graph's quiescent phase (leaves done, root mid-burn)
/// and return an idle worker to kill. Panics if the cluster never settles.
fn pick_idle_victim(ws: &[WorkerHandle]) -> usize {
    // By 1.2 s every leaf (≤ 100 ms of total work) has finished and its
    // replica pushes have been confirmed; the 3 s root is still burning.
    std::thread::sleep(std::time::Duration::from_millis(1_200));
    let deadline = std::time::Instant::now() + std::time::Duration::from_millis(1_200);
    loop {
        let busy: Vec<bool> = ws.iter().map(|w| w.busy()).collect();
        if busy.iter().filter(|b| **b).count() == 1 {
            return busy.iter().position(|b| !**b).expect("an idle worker exists");
        }
        assert!(
            std::time::Instant::now() < deadline,
            "cluster never quiesced to exactly one busy worker: {busy:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}

#[test]
fn replicated_outputs_make_idle_worker_death_trivial() {
    // k = 2: every leaf output lives on two workers by the time the kill
    // lands, and the victim is idle — so its death must be absorbed as a
    // pure who-has purge: no recovery pass, no recomputed task, and the
    // sink completes by fetching each leaf from its surviving replica.
    let srv = server_replicated(2);
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 3);
    let g = stem_graph(40, 3_000_000);
    let caddr = addr.clone();
    let client_thread = std::thread::spawn(move || {
        let mut c = Client::connect(&caddr, "repl-kill").unwrap();
        c.run_graph(&g).expect("run must survive the idle worker's death")
    });
    let victim = pick_idle_victim(&ws);
    ws[victim].shutdown();
    let res = client_thread.join().unwrap();
    assert_eq!(res.n_tasks, 42);
    let reports = srv.reports();
    assert_eq!(reports.len(), 1);
    assert_eq!(
        reports[0].recoveries, 0,
        "replicated data death is a trivial purge, not a recovery: {reports:?}"
    );
    assert_eq!(reports[0].tasks_recomputed, 0, "nothing re-executed: {reports:?}");
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn sole_replica_death_forces_recompute() {
    // The k = 1 contrast: identical graph, identical kill point, but the
    // idle victim now holds the *only* copy of every leaf it produced —
    // the server must resurrect those leaves (recoveries ≥ 1, recomputed
    // tasks ≥ 1) before the sink can run.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 3);
    let g = stem_graph(40, 3_000_000);
    let caddr = addr.clone();
    let client_thread = std::thread::spawn(move || {
        let mut c = Client::connect(&caddr, "sole-kill").unwrap();
        c.run_graph(&g).expect("recovery must still complete the run")
    });
    let victim = pick_idle_victim(&ws);
    ws[victim].shutdown();
    let res = client_thread.join().unwrap();
    assert_eq!(res.n_tasks, 42);
    let reports = srv.reports();
    assert_eq!(reports.len(), 1);
    assert!(reports[0].recoveries >= 1, "sole copies were lost: {reports:?}");
    assert!(reports[0].tasks_recomputed >= 1, "lost leaves re-ran: {reports:?}");
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn worker_killed_during_replica_push_completes() {
    // ~1 MiB outputs keep put-data pushes and their replica-added
    // confirmations in flight for much of the run; a kill in the middle
    // races the death against pushes to, from and through the victim. The
    // run must complete whatever the interleaving hits — half-received
    // replicas are never counted (the server only trusts confirmations
    // from the *receiving* peer), so recovery sees a consistent who-has.
    let srv = server_replicated(2);
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let g = {
        use rsds::taskgraph::{GraphBuilder, Payload};
        let mut b = GraphBuilder::new();
        let mut leaves = Vec::new();
        for i in 0..60 {
            leaves.push(b.add(format!("big-{i}"), vec![], 20_000, 1 << 20, Payload::BusyWait));
        }
        b.add("sink", leaves, 1_000, 100, Payload::MergeInputs);
        b.build("push-kill").expect("valid graph")
    };
    let mut client = Client::connect(&addr, "push-kill").unwrap();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(150));
        victim.shutdown();
    });
    let res = client.run_graph(&g).expect("run must survive a death mid-push");
    killer.join().unwrap();
    assert_eq!(res.n_tasks, 61);
    assert_eq!(srv.reports().len(), 1);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn fetch_failover_uses_surviving_replica() {
    // Replica-aware fetch in isolation, on a hand-rolled control plane: a
    // fake server registers two real workers, seeds worker 2 with a
    // replica via put-data, then hands worker 1 a compute whose input
    // names a *dead* primary address first and worker 2 only as the
    // alternate. The worker must fail over to the surviving replica and
    // finish — no `fetch-failed` retry round-trip through the server.
    use rsds::protocol::{decode_msg, RunId, TaskInputLoc};
    use rsds::taskgraph::TaskId;
    use std::net::TcpListener;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Welcome both workers from a side thread (run_worker blocks on it).
    let acceptor = std::thread::spawn(move || {
        (0..2u32)
            .map(|i| {
                let (mut s, _) = listener.accept().unwrap();
                s.set_read_timeout(Some(std::time::Duration::from_secs(20))).unwrap();
                let frame = read_frame(&mut s).unwrap();
                let msg = decode_msg(&frame).unwrap();
                assert!(matches!(msg, Msg::RegisterWorker { .. }), "{:?}", msg.op());
                write_frame(&mut s, &encode_msg(&Msg::Welcome { id: i })).unwrap();
                s
            })
            .collect::<Vec<_>>()
    });
    let w1 = run_worker(WorkerConfig {
        server_addr: addr.clone(),
        name: "fo-w1".into(),
        ncores: 1,
        node: 0,
        memory_limit: None,
        data_plane: Default::default(),
    })
    .unwrap();
    let w2 = run_worker(WorkerConfig {
        server_addr: addr.clone(),
        name: "fo-w2".into(),
        ncores: 1,
        node: 0,
        memory_limit: None,
        data_plane: Default::default(),
    })
    .unwrap();
    let mut conns = acceptor.join().unwrap();

    // Seed the replica on worker 2 through its data plane, and wait for
    // its replica-added confirmation so the copy is known readable.
    let run = RunId(7);
    let input = TaskId(0);
    let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
    {
        let mut s = TcpStream::connect(&w2.data_addr).unwrap();
        write_frame(&mut s, &encode_msg(&Msg::PutData { run, task: input, data: payload }))
            .unwrap();
        let confirm = decode_msg(&read_frame(&mut conns[1]).unwrap()).unwrap();
        assert!(
            matches!(confirm, Msg::ReplicaAdded { run: r, task: t } if r == run && t == input),
            "{:?}",
            confirm.op()
        );
    }

    // A primary address that refuses connections: bind, record, drop.
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };

    // Even task id ⇒ the rotating fetch starts at the primary, so the
    // worker really does try the dead source before failing over.
    let compute = Msg::ComputeTask {
        run,
        task: TaskId(2),
        key: "failover-sink".into(),
        payload: rsds::taskgraph::Payload::MergeInputs,
        duration_us: 0,
        output_size: 64,
        inputs: vec![TaskInputLoc {
            task: input,
            addr: dead_addr,
            alts: vec![w2.data_addr.clone()],
            nbytes: 10_000,
        }],
        priority: 0,
        consumers: 0,
        cores: 1,
    };
    write_frame(&mut conns[0], &encode_msg(&compute)).unwrap();
    let reply = decode_msg(&read_frame(&mut conns[0]).unwrap()).unwrap();
    match reply {
        Msg::TaskFinished(info) => {
            assert_eq!((info.run, info.task), (run, TaskId(2)));
            assert_eq!(info.nbytes, 64);
        }
        other => panic!("expected task-finished via the replica, got {:?}", other.op()),
    }
    w1.shutdown();
    w2.shutdown();
}

#[test]
fn memory_budget_spills_and_completes() {
    // A 64 KiB store budget on the only worker, 32 × 16 KiB live leaf
    // outputs: the graph cannot fit in memory, so completion proves the
    // LRU spill tier wrote entries out and the sink's gather transparently
    // restored them.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let w = run_worker(WorkerConfig {
        server_addr: addr.clone(),
        name: "budget-w0".into(),
        ncores: 1,
        node: 0,
        memory_limit: Some(64 * 1024),
        data_plane: Default::default(),
    })
    .expect("worker start");
    let g = {
        use rsds::taskgraph::{GraphBuilder, Payload};
        let mut b = GraphBuilder::new();
        let mut leaves = Vec::new();
        for i in 0..32 {
            leaves.push(b.add(format!("chunk-{i}"), vec![], 1_000, 16 * 1024, Payload::NoOp));
        }
        b.add("sink", leaves, 1_000, 1_024, Payload::MergeInputs);
        b.build("oversized").expect("valid graph")
    };
    let mut client = Client::connect(&addr, "spiller").unwrap();
    let res = client.run_graph(&g).expect("budgeted run must complete via spill");
    assert_eq!(res.n_tasks, 33);
    let (spills, restores) = w.spill_stats();
    assert!(spills > 0, "live outputs exceeded the budget, something must spill");
    assert!(restores > 0, "the sink's gather restored spilled inputs");
    w.shutdown();
    srv.shutdown();
}

// ---- incremental graphs + resource slots (PR 9 tentpole) ----

/// A heterogeneous pool: workers with 1, 2 and 4 core slots.
fn mixed_workers(addr: &str) -> Vec<WorkerHandle> {
    [1u32, 2, 4]
        .iter()
        .enumerate()
        .map(|(i, &ncores)| {
            run_worker(WorkerConfig {
                server_addr: addr.to_string(),
                name: format!("mix-w{i}"),
                ncores,
                node: 0,
                memory_limit: None,
                data_plane: Default::default(),
            })
            .expect("worker start")
        })
        .collect()
}

#[test]
fn incremental_submission_matches_one_shot_over_tcp() {
    // PR 9 acceptance: a graph submitted in ≥ 3 incremental extensions over
    // a mixed 1/2/4-core cluster completes identically to the one-shot
    // submission for all three schedulers. The tree's merge payloads
    // consume real input bytes across extension boundaries, so completion
    // proves the data plane handed every extension task the same bytes the
    // one-shot run produced.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = mixed_workers(&addr);
    let graph = graphgen::with_cores(&graphgen::tree(6), &[1, 2]);
    let mut c = Client::connect(&addr, "inc-parity").unwrap();
    for sched in ["random", "ws", "dask-ws"] {
        let oneshot = c.run_graph_with(&graph, Some(sched)).unwrap();
        assert_eq!(oneshot.n_tasks, graph.len() as u64, "{sched}: one-shot");

        let (base, exts) = graphgen::split_incremental(&graph, 4);
        assert!(exts.len() >= 3, "graph large enough for 3+ extensions");
        let run = c.submit_open(&base, Some(sched)).unwrap();
        let n_exts = exts.len();
        for (i, batch) in exts.into_iter().enumerate() {
            c.extend(run, batch, i + 1 == n_exts).unwrap();
        }
        let inc = c.wait(run).unwrap();
        assert_eq!(inc.n_tasks, oneshot.n_tasks, "{sched}: incremental parity");
    }
    assert_eq!(srv.report_count(), 6);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn extend_after_base_finished_over_tcp() {
    // The re-pin path end to end: the base (leaves only) finishes and its
    // outputs sit pinned on the workers; the extension then adds the sink
    // consuming all of them. The server must pin-data the new consumer
    // counts onto the live outputs and the sink must fetch every one.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut c = Client::connect(&addr, "late-extend").unwrap();
    let g = graphgen::merge(30);
    let (base, exts) = graphgen::split_incremental(&g, 2);
    let run = c.submit_open(&base, None).unwrap();
    // The base is a few ms of work; by now it has long finished and the
    // run is idling open.
    std::thread::sleep(std::time::Duration::from_millis(700));
    let n_exts = exts.len();
    for (i, batch) in exts.into_iter().enumerate() {
        c.extend(run, batch, i + 1 == n_exts).unwrap();
    }
    let res = c.wait(run).unwrap();
    assert_eq!(res.n_tasks, 31);
    assert_eq!(srv.reports().len(), 1);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn extend_during_recovery_over_tcp() {
    // Extension racing an in-flight lineage recovery: a worker dies with
    // base assignments (and likely outputs) on it, and the extension lands
    // while the server is resurrecting. The run must absorb both — every
    // task of the extended graph completes.
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let mut ws = workers(&addr, 3);
    let victim = ws.remove(0);
    let mut c = Client::connect(&addr, "extend-recover").unwrap();
    let g = graphgen::merge_slow(60, 100_000); // ~6 s of task work
    let (base, exts) = graphgen::split_incremental(&g, 2);
    let run = c.submit_open(&base, None).unwrap();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(300));
        victim.shutdown();
    });
    // Lands right around the kill + recovery window.
    std::thread::sleep(std::time::Duration::from_millis(400));
    let n_exts = exts.len();
    for (i, batch) in exts.into_iter().enumerate() {
        c.extend(run, batch, i + 1 == n_exts).unwrap();
    }
    let res = c.wait(run).expect("open run must survive the worker death");
    killer.join().unwrap();
    assert_eq!(res.n_tasks, 61);
    let reports = srv.reports();
    assert_eq!(reports.len(), 1);
    assert!(reports[0].recoveries >= 1, "the death was absorbed by recovery: {reports:?}");
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
}

#[test]
fn replica_ack_after_run_retirement_is_ignored_over_tcp() {
    // Satellite: a replica-added confirmation landing after its run retired
    // (or for a run that never existed) must be dropped silently — the
    // server stays fully operational. A raw registered worker delivers the
    // stale acks deterministically, then keeps answering assignments like
    // a zero worker so later runs can still complete on the shared pool.
    use rsds::protocol::{decode_msg, RunId, TaskFinishedInfo};
    use rsds::taskgraph::TaskId;

    let srv = server_replicated(2);
    let addr = srv.addr.to_string();
    let ws = workers(&addr, 2);
    let mut client = Client::connect(&addr, "retire-race").unwrap();
    let done = client.run_graph(&graphgen::merge(20)).unwrap();

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(20))).unwrap();
    write_frame(
        &mut s,
        &encode_msg(&Msg::RegisterWorker {
            name: "late-acker".into(),
            ncores: 1,
            node: 0,
            // No data address: replica placement skips this worker, so the
            // real pool never pushes toward it.
            data_addr: String::new(),
        }),
    )
    .unwrap();
    let welcome = decode_msg(&read_frame(&mut s).unwrap()).unwrap();
    assert!(matches!(welcome, Msg::Welcome { .. }), "{:?}", welcome.op());
    // Ack for the retired run, then for a run that never existed.
    write_frame(&mut s, &encode_msg(&Msg::ReplicaAdded { run: done.run, task: TaskId(0) }))
        .unwrap();
    write_frame(
        &mut s,
        &encode_msg(&Msg::ReplicaAdded { run: RunId(u32::MAX), task: TaskId(0) }),
    )
    .unwrap();
    let acker = std::thread::spawn(move || {
        // Finish any assignment instantly; refuse steals (the task already
        // "ran" here). Exits when the server closes the socket.
        while let Ok(frame) = read_frame(&mut s) {
            let Ok(msg) = decode_msg(&frame) else { break };
            let reply = match msg {
                Msg::ComputeTask { run, task, output_size, .. } => {
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 1,
                    })
                }
                Msg::StealRequest { run, task } => Msg::StealResponse { run, task, ok: false },
                _ => continue,
            };
            if write_frame(&mut s, &encode_msg(&reply)).is_err() {
                break;
            }
        }
    });
    // Independent tasks only: an output "stored" on the ack-only worker is
    // never fetched, so the run's completion doesn't depend on its
    // (nonexistent) data plane.
    let g = {
        use rsds::taskgraph::{GraphBuilder, Payload};
        let mut b = GraphBuilder::new();
        for i in 0..20 {
            b.add(format!("ind-{i}"), vec![], 1_000, 64, Payload::NoOp);
        }
        b.build("independent").expect("valid graph")
    };
    let res = client.run_graph(&g).expect("server must shrug off the stale acks");
    assert_eq!(res.n_tasks, 20);
    for w in &ws {
        w.shutdown();
    }
    srv.shutdown();
    acker.join().unwrap();
}

#[test]
fn unregistered_peer_messages_ignored() {
    let srv = server("ws");
    let addr = srv.addr.to_string();
    let mut s = TcpStream::connect(&addr).unwrap();
    // A task-finished from a peer that never registered: logged + ignored.
    write_frame(
        &mut s,
        &encode_msg(&Msg::TaskFinished(rsds::protocol::TaskFinishedInfo {
            run: rsds::protocol::RunId(0),
            task: rsds::taskgraph::TaskId(0),
            nbytes: 0,
            duration_us: 0,
        })),
    )
    .unwrap();
    // Server must still work.
    let ws = workers(&addr, 1);
    let mut client = Client::connect(&addr, "c").unwrap();
    assert_eq!(client.run_graph(&graphgen::merge(5)).unwrap().n_tasks, 6);
    ws[0].shutdown();
    srv.shutdown();
}
