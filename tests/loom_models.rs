//! Exhaustive interleaving models for the lock-protected cores, run under
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_models`.
//!
//! Each model rebuilds one of the repo's real concurrency cores — the
//! worker's one-mutex [`TaskQueue`], the report window behind the
//! [`ServerHandle`] mutex, the writer-registry/`flush_batches` shutdown
//! protocol, and the runtime's global-init pattern — from the *production
//! types* behind the [`rsds::sync`] shim, and explores every
//! distinguishable schedule with [`rsds::modelcheck`] (the offline loom
//! stand-in). The `seeded_*` models lock known bugs in as regressions:
//! each reconstructs a protocol violation (the PR 4 count-based-watermark
//! bug, naive once-init) and asserts the explorer *catches* it — proving
//! the checker checks, per `docs/verification.md`.
//!
//! [`ServerHandle`]: rsds::server::ServerHandle
//! [`TaskQueue`]: rsds::worker::queue::TaskQueue

#![cfg(loom)]

use rsds::modelcheck::{model, model_fails};
use rsds::protocol::{encode_msg, ComputeTaskView, Msg, RunId, TaskInputLoc};
use rsds::server::{flush_batches, pool_put, BoundedWindow, BufPool};
use rsds::sync::atomic::{AtomicUsize, Ordering};
use rsds::sync::{thread, Arc, Condvar, Mutex};
use rsds::taskgraph::{Payload, TaskId};
use rsds::worker::queue::{FetchPlan, TaskQueue};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex as StdMutex;

/// An encoded `compute-task` frame (decoded to a borrowed view per use,
/// exactly like the worker's reader thread).
fn compute_frame(run: u32, task: u32, priority: i64, addr: &str) -> Vec<u8> {
    encode_msg(&Msg::ComputeTask {
        run: RunId(run),
        task: TaskId(task),
        key: format!("k-{run}-{task}"),
        payload: Payload::BusyWait,
        duration_us: 7,
        output_size: 64,
        inputs: vec![TaskInputLoc { task: TaskId(0), addr: addr.into(), nbytes: 5 }],
        priority,
    })
}

fn enqueue_frame(q: &Mutex<TaskQueue>, bytes: &[u8]) {
    let view = ComputeTaskView::decode(bytes).expect("frame decodes");
    q.lock().unwrap().enqueue(&view).expect("enqueue");
}

// ---------------------------------------------------------------------------
// TaskQueue: enqueue / pop_into / arena reset
// ---------------------------------------------------------------------------

/// A concurrent enqueuer (the reader thread) and popper (the executor)
/// must hand every task across exactly once, with its interned strings
/// resolved correctly even when a pop drains the queue and the next
/// enqueue resets the input-location pools mid-race.
#[test]
fn queue_enqueue_pop_delivers_each_task_once() {
    let f1 = compute_frame(0, 1, 10, "10.0.0.1:9000");
    let f2 = compute_frame(0, 2, 20, "10.0.0.2:9000");
    model(move || {
        let q = Arc::new(Mutex::new(TaskQueue::new()));
        let producer = {
            let q = Arc::clone(&q);
            let (f1, f2) = (f1.clone(), f2.clone());
            thread::spawn(move || {
                enqueue_frame(&q, &f1);
                enqueue_frame(&q, &f2);
            })
        };
        // The executor side: two bounded pop attempts racing the enqueues,
        // then a post-join drain — every task must surface exactly once.
        let mut plan = FetchPlan::new();
        let mut seen: Vec<(TaskId, String, String)> = Vec::new();
        for _ in 0..2 {
            if let Some(p) = q.lock().unwrap().pop_into(&mut plan) {
                seen.push((p.task, plan.key().to_string(), plan.input(0).2.to_string()));
            }
        }
        producer.join().unwrap();
        while let Some(p) = q.lock().unwrap().pop_into(&mut plan) {
            seen.push((p.task, plan.key().to_string(), plan.input(0).2.to_string()));
        }
        seen.sort();
        assert_eq!(
            seen,
            vec![
                (TaskId(1), "k-0-1".to_string(), "10.0.0.1:9000".to_string()),
                (TaskId(2), "k-0-2".to_string(), "10.0.0.2:9000".to_string()),
            ],
            "every task exactly once, arenas resolved under every schedule"
        );
        let q = q.lock().unwrap();
        assert!(q.is_empty());
        assert!(q.input_pool_len() <= 2, "pool reset invariant broke");
    });
}

/// `cancel-compute` (`drop_queued`) racing the executor's `pop_into` on
/// the same task: exactly one side may win — the task is either retracted
/// or executed, never both, never neither.
#[test]
fn queue_drop_queued_vs_pop_is_exactly_once() {
    let frame = compute_frame(0, 1, 10, "10.0.0.1:9000");
    model(move || {
        let q = Arc::new(Mutex::new(TaskQueue::new()));
        enqueue_frame(&q, &frame);
        let canceller = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.lock().unwrap().drop_queued(RunId(0), TaskId(1)))
        };
        let mut plan = FetchPlan::new();
        let popped = q.lock().unwrap().pop_into(&mut plan).is_some();
        let dropped = canceller.join().unwrap();
        assert!(
            popped ^ dropped,
            "task must be executed XOR cancelled (popped={popped}, dropped={dropped})"
        );
        let q = q.lock().unwrap();
        assert!(q.is_empty());
        assert!(!q.is_pending(RunId(0), TaskId(1)));
    });
}

/// `release-run` racing a late enqueue for the same run: because heap and
/// arenas live behind one mutex, the pop must observe either the complete
/// task (correct key and address) or nothing — never a queued entry whose
/// arena was purged out from under it.
#[test]
fn queue_release_run_vs_enqueue_is_atomic() {
    let frame = compute_frame(0, 1, 10, "10.0.0.1:9000");
    model(move || {
        let q = Arc::new(Mutex::new(TaskQueue::new()));
        let releaser = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.lock().unwrap().release_run(RunId(0)))
        };
        enqueue_frame(&q, &frame);
        releaser.join().unwrap();
        let mut plan = FetchPlan::new();
        if let Some(p) = q.lock().unwrap().pop_into(&mut plan) {
            // Enqueue happened after (or before-and-survived) the release:
            // the entry must be whole.
            assert_eq!(p.task, TaskId(1));
            assert_eq!(plan.key(), "k-0-1", "arena purged under a live heap entry");
            assert_eq!(plan.input(0).2, "10.0.0.1:9000");
        }
    });
}

/// The worker's executor parks on the queue condvar
/// (`worker/mod.rs::executor_loop`); the reader enqueues then notifies.
/// Under the repo's lock discipline (predicate checked under the same
/// mutex, waits in a re-checking loop) no schedule may lose the wakeup.
#[test]
fn executor_wakeup_is_never_lost() {
    let frame = compute_frame(0, 1, 10, "");
    model(move || {
        let shared = Arc::new((Mutex::new(TaskQueue::new()), Condvar::new()));
        let reader = {
            let shared = Arc::clone(&shared);
            let frame = frame.clone();
            thread::spawn(move || {
                let (q, cv) = &*shared;
                let view = ComputeTaskView::decode(&frame).expect("frame decodes");
                q.lock().unwrap().enqueue(&view).expect("enqueue");
                cv.notify_all();
            })
        };
        let (q, cv) = &*shared;
        let mut guard = cv
            .wait_while(q.lock().unwrap(), |q| q.is_empty())
            .unwrap();
        let mut plan = FetchPlan::new();
        assert!(guard.pop_into(&mut plan).is_some());
        drop(guard);
        reader.join().unwrap();
    });
}

// ---------------------------------------------------------------------------
// BoundedWindow / ReportStore: watermark exactly-once across eviction gaps
// ---------------------------------------------------------------------------

/// One poll against the shared window: returns the fresh items, the next
/// watermark, and how many items the retention window evicted unseen.
fn poll(w: &Mutex<BoundedWindow<u64>>, watermark: usize) -> (Vec<u64>, usize, usize) {
    let g = w.lock().unwrap();
    assert_eq!(g.dropped() + g.len(), g.total(), "window accounting broke");
    let (fresh, next) = g.since(watermark);
    let missed = (next - watermark) - fresh.len();
    (fresh.to_vec(), next, missed)
}

/// The PR 4 protocol, model-checked: a poller that advances by the
/// *returned watermark* receives every report exactly once, no matter how
/// the publisher's pushes and the retention window's evictions interleave
/// with its polls — evicted reports are each counted missed exactly once.
#[test]
fn reports_since_is_exactly_once_across_eviction_gaps() {
    model(|| {
        let w = Arc::new(Mutex::new(BoundedWindow::<u64>::new(1)));
        let publisher = {
            let w = Arc::clone(&w);
            thread::spawn(move || {
                for v in 0..3 {
                    w.lock().unwrap().push(v);
                }
            })
        };
        let mut watermark = 0;
        let mut delivered: Vec<u64> = Vec::new();
        let mut missed = 0;
        for _ in 0..2 {
            let (fresh, next, gap) = poll(&w, watermark);
            delivered.extend(fresh);
            missed += gap;
            watermark = next;
        }
        publisher.join().unwrap();
        let (fresh, next, gap) = poll(&w, watermark);
        delivered.extend(fresh);
        missed += gap;
        watermark = next;
        assert_eq!(watermark, 3);
        assert_eq!(
            delivered.len() + missed,
            3,
            "every report delivered or counted missed: {delivered:?} + {missed}"
        );
        let mut unique = delivered.clone();
        unique.dedup();
        assert_eq!(unique, delivered, "duplicate delivery: {delivered:?}");
        let mut sorted = delivered.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, delivered, "reports delivered out of order");
    });
}

/// Seeded regression: the pre-PR-4 client protocol — advancing the
/// watermark by counting returned reports instead of using the returned
/// watermark — re-receives the window's tail after an eviction gap. The
/// explorer must find that schedule and fail the model; this proves the
/// checker would have caught the original bug.
#[test]
fn seeded_count_based_watermark_bug_is_caught() {
    let msg = model_fails(|| {
        let w = Arc::new(Mutex::new(BoundedWindow::<u64>::new(1)));
        let publisher = {
            let w = Arc::clone(&w);
            thread::spawn(move || {
                for v in 0..3 {
                    w.lock().unwrap().push(v);
                }
            })
        };
        let mut watermark = 0;
        let mut delivered: Vec<u64> = Vec::new();
        for _ in 0..2 {
            let (fresh, _next, _gap) = poll(&w, watermark);
            delivered.extend(fresh);
            // BUG under test (pre-PR-4): count only what was returned.
            watermark = delivered.len();
        }
        publisher.join().unwrap();
        let (fresh, _next, _gap) = poll(&w, watermark);
        delivered.extend(fresh);
        let mut unique = delivered.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), delivered.len(), "duplicate delivery: {delivered:?}");
    });
    assert!(msg.contains("duplicate delivery"), "wrong failure: {msg}");
}

// ---------------------------------------------------------------------------
// Writer registry: flush_batches vs shutdown
// ---------------------------------------------------------------------------

/// `flush_batches` racing `ServerHandle::shutdown`'s writer-registry
/// drain: the coalesced batch must be delivered to the writer XOR
/// recycled into the buffer pool — dropped-on-the-floor would leak the
/// buffer, double-accounted would alias it.
#[test]
fn flush_batches_vs_shutdown_conserves_buffers() {
    model(|| {
        let writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel::<Vec<u8>>();
        writers.lock().unwrap().insert(1, tx);
        let shutdown = {
            let writers = Arc::clone(&writers);
            // The shutdown drain: writer senders dropped wholesale.
            thread::spawn(move || writers.lock().unwrap().clear())
        };
        let mut batches: HashMap<u64, Vec<u8>> = HashMap::new();
        batches.insert(1, b"frame-bytes".to_vec());
        let mut scratch = Vec::new();
        flush_batches(&mut batches, &mut scratch, &writers, &pool, 0);
        shutdown.join().unwrap();
        let delivered = rx.try_iter().count();
        let pooled = pool.lock().unwrap().len();
        assert!(batches.is_empty(), "batch neither flushed nor recycled");
        assert_eq!(
            delivered + pooled,
            1,
            "buffer conservation broke (delivered={delivered}, pooled={pooled})"
        );
    });
}

/// Same race, but the writer *thread* is already gone (receiver dropped,
/// as after a peer disconnect): the send fails and the error path must
/// recycle the batch it hands back.
#[test]
fn flush_batches_send_failure_recycles_the_batch() {
    model(|| {
        let writers: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel::<Vec<u8>>();
        writers.lock().unwrap().insert(1, tx);
        let rx_slot: Arc<StdMutex<Option<Receiver<Vec<u8>>>>> =
            Arc::new(StdMutex::new(Some(rx)));
        let killer = {
            let rx_slot = Arc::clone(&rx_slot);
            thread::spawn(move || drop(rx_slot.lock().unwrap().take()))
        };
        let mut batches: HashMap<u64, Vec<u8>> = HashMap::new();
        batches.insert(1, b"frame-bytes".to_vec());
        let mut scratch = Vec::new();
        flush_batches(&mut batches, &mut scratch, &writers, &pool, 0);
        killer.join().unwrap();
        let delivered = rx_slot
            .lock()
            .unwrap()
            .as_ref()
            .map_or(0, |rx| rx.try_iter().count());
        let pooled = pool.lock().unwrap().len();
        assert!(batches.is_empty());
        assert_eq!(delivered + pooled, 1, "send-failure path leaked the batch");
    });
}

/// The conservation helper itself must round-trip: what `pool_put`
/// accepts, `pool_get` hands back (a sanity anchor for the two models
/// above — if pooling silently dropped small buffers, `pooled` would
/// undercount and the models would pass vacuously).
#[test]
fn pool_round_trips_small_buffers() {
    model(|| {
        let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
        pool_put(&pool, Vec::with_capacity(64));
        assert_eq!(pool.lock().unwrap().len(), 1);
    });
}

// ---------------------------------------------------------------------------
// Runtime global init (runtime/mod.rs::global)
// ---------------------------------------------------------------------------

/// The `Runtime::global` pattern with the init lock (PJRT client stubbed
/// by a construction counter): two racing first callers must construct
/// exactly once. Mirrors `runtime/mod.rs` — `GLOBAL` is the slot mutex,
/// `INIT` serializes construction.
#[test]
fn global_init_races_single_construction() {
    model(|| {
        let slot: Arc<Mutex<Option<u32>>> = Arc::new(Mutex::new(None));
        let init: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
        let ctors = Arc::new(AtomicUsize::new(0));
        let get = |slot: &Mutex<Option<u32>>, init: &Mutex<()>, ctors: &AtomicUsize| {
            if slot.lock().unwrap().is_some() {
                return;
            }
            let _init = init.lock().unwrap();
            let mut g = slot.lock().unwrap();
            if g.is_none() {
                ctors.fetch_add(1, Ordering::SeqCst);
                *g = Some(42);
            }
        };
        let racer = {
            let (slot, init, ctors) =
                (Arc::clone(&slot), Arc::clone(&init), Arc::clone(&ctors));
            thread::spawn(move || get(&slot, &init, &ctors))
        };
        get(&slot, &init, &ctors);
        racer.join().unwrap();
        assert_eq!(ctors.load(Ordering::SeqCst), 1, "PJRT client constructed twice");
        assert_eq!(*slot.lock().unwrap(), Some(42));
    });
}

/// Seeded regression: the pre-PR-6 `Runtime::global` — a bare
/// check-then-construct with no init lock — lets two first callers both
/// run `Runtime::new`. The explorer must find the double construction.
#[test]
fn seeded_naive_global_init_double_constructs() {
    let msg = model_fails(|| {
        let slot: Arc<Mutex<Option<u32>>> = Arc::new(Mutex::new(None));
        let ctors = Arc::new(AtomicUsize::new(0));
        // BUG under test: `if GLOBAL.get().is_none() { GLOBAL.set(new()?) }`.
        let get = |slot: &Mutex<Option<u32>>, ctors: &AtomicUsize| {
            let vacant = slot.lock().unwrap().is_none();
            if vacant {
                ctors.fetch_add(1, Ordering::SeqCst);
                let mut g = slot.lock().unwrap();
                if g.is_none() {
                    *g = Some(42);
                }
            }
        };
        let racer = {
            let (slot, ctors) = (Arc::clone(&slot), Arc::clone(&ctors));
            thread::spawn(move || get(&slot, &ctors))
        };
        get(&slot, &ctors);
        racer.join().unwrap();
        assert_eq!(ctors.load(Ordering::SeqCst), 1, "PJRT client constructed twice");
    });
    assert!(msg.contains("constructed twice"), "wrong failure: {msg}");
}
