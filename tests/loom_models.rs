//! Exhaustive interleaving models for the lock-protected cores, run under
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_models`.
//!
//! Each model rebuilds one of the repo's real concurrency cores — the
//! worker's one-mutex [`TaskQueue`], the object store's spill/restore
//! slot discipline ([`ObjectStore`]), the data plane's peer-link pool
//! (checkout vs dead-link eviction, [`LinkPool`]), the report window
//! behind the [`ServerHandle`] mutex, the cross-shard
//! forward/worker-death protocol (`deliver_forward`), and the runtime's
//! global-init pattern — from the
//! *production types* behind the [`rsds::sync`] shim, and explores every
//! distinguishable schedule with [`rsds::modelcheck`] (the offline loom
//! stand-in). The `seeded_*` models lock known bugs in as regressions:
//! each reconstructs a protocol violation (the PR 4 count-based-watermark
//! bug, naive once-init, an unlocked spill-slot restore) and asserts the
//! explorer *catches* it — proving the checker checks, per
//! `docs/verification.md`.
//!
//! [`ServerHandle`]: rsds::server::ServerHandle
//! [`TaskQueue`]: rsds::worker::queue::TaskQueue

#![cfg(loom)]

use rsds::modelcheck::{model, model_fails};
use rsds::protocol::{encode_msg, ComputeTaskView, Msg, RunId, TaskInputLoc};
use rsds::server::{deliver_forward, pool_get, pool_put, BoundedWindow, BufPool};
use rsds::sync::atomic::{AtomicUsize, Ordering};
use rsds::sync::{thread, Arc, Condvar, Mutex};
use rsds::taskgraph::{Payload, TaskId};
use rsds::worker::dataplane::LinkPool;
use rsds::worker::queue::{FetchPlan, TaskQueue};
use rsds::worker::spill::{MemSpill, SpillBackend};
use rsds::worker::store::{DataKey, Lookup, ObjectStore};
use std::collections::HashMap;
use std::sync::mpsc::channel;

/// An encoded `compute-task` frame (decoded to a borrowed view per use,
/// exactly like the worker's reader thread).
fn compute_frame(run: u32, task: u32, priority: i64, addr: &str) -> Vec<u8> {
    encode_msg(&Msg::ComputeTask {
        run: RunId(run),
        task: TaskId(task),
        key: format!("k-{run}-{task}"),
        payload: Payload::BusyWait,
        duration_us: 7,
        output_size: 64,
        inputs: vec![TaskInputLoc {
            task: TaskId(0),
            addr: addr.into(),
            // A replica alternate rides along so the alt pool's
            // reset-on-drain is part of every queue model.
            alts: if addr.is_empty() { vec![] } else { vec![format!("alt.{addr}")] },
            nbytes: 5,
        }],
        priority,
        consumers: 1,
        cores: 1,
    })
}

fn enqueue_frame(q: &Mutex<TaskQueue>, bytes: &[u8]) {
    let view = ComputeTaskView::decode(bytes).expect("frame decodes");
    q.lock().unwrap().enqueue(&view).expect("enqueue");
}

// ---------------------------------------------------------------------------
// TaskQueue: enqueue / pop_into / arena reset
// ---------------------------------------------------------------------------

/// A concurrent enqueuer (the reader thread) and popper (the executor)
/// must hand every task across exactly once, with its interned strings
/// resolved correctly even when a pop drains the queue and the next
/// enqueue resets the input-location pools mid-race.
#[test]
fn queue_enqueue_pop_delivers_each_task_once() {
    let f1 = compute_frame(0, 1, 10, "10.0.0.1:9000");
    let f2 = compute_frame(0, 2, 20, "10.0.0.2:9000");
    model(move || {
        let q = Arc::new(Mutex::new(TaskQueue::new()));
        let producer = {
            let q = Arc::clone(&q);
            let (f1, f2) = (f1.clone(), f2.clone());
            thread::spawn(move || {
                enqueue_frame(&q, &f1);
                enqueue_frame(&q, &f2);
            })
        };
        // The executor side: two bounded pop attempts racing the enqueues,
        // then a post-join drain — every task must surface exactly once.
        let mut plan = FetchPlan::new();
        let mut seen: Vec<(TaskId, String, String, String)> = Vec::new();
        for _ in 0..2 {
            if let Some(p) = q.lock().unwrap().pop_into(&mut plan) {
                seen.push((
                    p.task,
                    plan.key().to_string(),
                    plan.input(0).2.to_string(),
                    plan.input_alt(0, 0).to_string(),
                ));
            }
        }
        producer.join().unwrap();
        while let Some(p) = q.lock().unwrap().pop_into(&mut plan) {
            seen.push((
                p.task,
                plan.key().to_string(),
                plan.input(0).2.to_string(),
                plan.input_alt(0, 0).to_string(),
            ));
        }
        seen.sort();
        assert_eq!(
            seen,
            vec![
                (
                    TaskId(1),
                    "k-0-1".to_string(),
                    "10.0.0.1:9000".to_string(),
                    "alt.10.0.0.1:9000".to_string(),
                ),
                (
                    TaskId(2),
                    "k-0-2".to_string(),
                    "10.0.0.2:9000".to_string(),
                    "alt.10.0.0.2:9000".to_string(),
                ),
            ],
            "every task exactly once, arenas resolved under every schedule"
        );
        let q = q.lock().unwrap();
        assert!(q.is_empty());
        assert!(q.input_pool_len() <= 2, "pool reset invariant broke");
    });
}

/// `cancel-compute` (`drop_queued`) racing the executor's `pop_into` on
/// the same task: exactly one side may win — the task is either retracted
/// or executed, never both, never neither.
#[test]
fn queue_drop_queued_vs_pop_is_exactly_once() {
    let frame = compute_frame(0, 1, 10, "10.0.0.1:9000");
    model(move || {
        let q = Arc::new(Mutex::new(TaskQueue::new()));
        enqueue_frame(&q, &frame);
        let canceller = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.lock().unwrap().drop_queued(RunId(0), TaskId(1)))
        };
        let mut plan = FetchPlan::new();
        let popped = q.lock().unwrap().pop_into(&mut plan).is_some();
        let dropped = canceller.join().unwrap();
        assert!(
            popped ^ dropped,
            "task must be executed XOR cancelled (popped={popped}, dropped={dropped})"
        );
        let q = q.lock().unwrap();
        assert!(q.is_empty());
        assert!(!q.is_pending(RunId(0), TaskId(1)));
    });
}

/// `release-run` racing a late enqueue for the same run: because heap and
/// arenas live behind one mutex, the pop must observe either the complete
/// task (correct key and address) or nothing — never a queued entry whose
/// arena was purged out from under it.
#[test]
fn queue_release_run_vs_enqueue_is_atomic() {
    let frame = compute_frame(0, 1, 10, "10.0.0.1:9000");
    model(move || {
        let q = Arc::new(Mutex::new(TaskQueue::new()));
        let releaser = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.lock().unwrap().release_run(RunId(0)))
        };
        enqueue_frame(&q, &frame);
        releaser.join().unwrap();
        let mut plan = FetchPlan::new();
        if let Some(p) = q.lock().unwrap().pop_into(&mut plan) {
            // Enqueue happened after (or before-and-survived) the release:
            // the entry must be whole.
            assert_eq!(p.task, TaskId(1));
            assert_eq!(plan.key(), "k-0-1", "arena purged under a live heap entry");
            assert_eq!(plan.input(0).2, "10.0.0.1:9000");
        }
    });
}

/// The worker's executor parks on the queue condvar
/// (`worker/mod.rs::executor_loop`); the reader enqueues then notifies.
/// Under the repo's lock discipline (predicate checked under the same
/// mutex, waits in a re-checking loop) no schedule may lose the wakeup.
#[test]
fn executor_wakeup_is_never_lost() {
    let frame = compute_frame(0, 1, 10, "");
    model(move || {
        let shared = Arc::new((Mutex::new(TaskQueue::new()), Condvar::new()));
        let reader = {
            let shared = Arc::clone(&shared);
            let frame = frame.clone();
            thread::spawn(move || {
                let (q, cv) = &*shared;
                let view = ComputeTaskView::decode(&frame).expect("frame decodes");
                q.lock().unwrap().enqueue(&view).expect("enqueue");
                cv.notify_all();
            })
        };
        let (q, cv) = &*shared;
        let mut guard = cv
            .wait_while(q.lock().unwrap(), |q| q.is_empty())
            .unwrap();
        let mut plan = FetchPlan::new();
        assert!(guard.pop_into(&mut plan).is_some());
        drop(guard);
        reader.join().unwrap();
    });
}

// ---------------------------------------------------------------------------
// ObjectStore: spill/restore slot discipline (worker/store.rs, PR 8)
// ---------------------------------------------------------------------------

/// The evictor's three-step spill (`Resident → Spilling` under the lock,
/// backend write *outside* it, commit or abandon under the lock again)
/// racing the gather path's get-then-restore: under every schedule the
/// reader obtains the payload exactly once and intact — a hit on the
/// still-readable `Spilling` arc XOR a restore from the backend — and at
/// quiescence the bytes sit in exactly one tier with the slot never
/// double-freed nor read after free.
#[test]
fn store_spill_vs_fetch_never_tears_or_loses_bytes() {
    model(|| {
        let backend = Arc::new(MemSpill::new());
        let store = Arc::new(ObjectStore::new(Some(4), backend.clone()));
        let k: DataKey = (RunId(0), TaskId(1));
        let payload: Vec<u8> = (0..8u8).collect();
        assert!(store.insert(k, Arc::new(payload.clone()), 0));
        let evictor = {
            let store = Arc::clone(&store);
            thread::spawn(move || store.maybe_spill())
        };
        // The worker's gather path: hot get, cold restore on Spilled.
        let got = match store.get(&k) {
            Lookup::Hit(b) => b,
            Lookup::Spilled => store.restore(&k).expect("live key restores"),
            Lookup::Miss => panic!("pinned key vanished under eviction"),
        };
        assert_eq!(*got, payload, "torn read under the spill race");
        evictor.join().unwrap();
        assert_eq!(store.num_entries(), 1, "pinned entry must survive");
        assert_eq!(
            store.resident_bytes() + backend.spilled_bytes(),
            8,
            "bytes must live in exactly one tier at quiescence"
        );
        assert_eq!(backend.misuse_count(), 0, "slot double-freed or read after free");
    });
}

/// The last consumer lands while the evictor is mid-spill: whichever of
/// `Resident`/`Spilling`/`Spilled` the race leaves the entry in, the
/// self-evict must drop the bytes and exactly one side must free the
/// backend slot (`drop_entry` skips a `Spilling` slot so the in-flight
/// evictor's abandon step frees its own write).
#[test]
fn store_consume_vs_spill_frees_the_slot_exactly_once() {
    model(|| {
        let backend = Arc::new(MemSpill::new());
        let store = Arc::new(ObjectStore::new(Some(0), backend.clone()));
        let k: DataKey = (RunId(0), TaskId(1));
        assert!(store.insert(k, Arc::new(vec![0x5A; 6]), 1));
        let evictor = {
            let store = Arc::clone(&store);
            thread::spawn(move || store.maybe_spill())
        };
        let evicted = store.consume(&k);
        evictor.join().unwrap();
        assert!(evicted, "sole consumer must observe the self-evict");
        assert!(matches!(store.get(&k), Lookup::Miss));
        assert_eq!(store.num_entries(), 0);
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(backend.spilled_bytes(), 0, "slot leaked after consume");
        assert_eq!(backend.live_slots(), 0);
        assert_eq!(backend.misuse_count(), 0, "double free under the consume/spill race");
    });
}

/// Seeded regression: a restore that lets the slot id escape the critical
/// section — observe `Spilled(slot)`, drop the lock, then read and free —
/// is the naive shape [`ObjectStore::restore`] avoids by reading the
/// backend *under* the store lock. Two racing restorers then free the
/// slot twice; the explorer must find that schedule, and the backend's
/// misuse counter is what catches it.
#[test]
fn seeded_unlocked_restore_double_frees_the_slot() {
    let msg = model_fails(|| {
        let backend = Arc::new(MemSpill::new());
        let slot = backend.write(&[7u8; 4]).unwrap();
        // Naive entry state: Some(slot) = spilled, None = resident again.
        let entry: Arc<Mutex<Option<u64>>> = Arc::new(Mutex::new(Some(slot)));
        let restore = |entry: &Mutex<Option<u64>>, backend: &MemSpill| {
            // BUG under test: the slot id outlives the lock.
            let slot = match *entry.lock().unwrap() {
                Some(s) => s,
                None => return,
            };
            let _ = backend.read(slot);
            backend.free(slot);
            *entry.lock().unwrap() = None;
        };
        let racer = {
            let (entry, backend) = (Arc::clone(&entry), Arc::clone(&backend));
            thread::spawn(move || restore(&entry, &backend))
        };
        restore(&entry, &backend);
        racer.join().unwrap();
        assert_eq!(backend.misuse_count(), 0, "slot freed twice");
    });
    assert!(msg.contains("freed twice"), "wrong failure: {msg}");
}

// ---------------------------------------------------------------------------
// BoundedWindow / ReportStore: watermark exactly-once across eviction gaps
// ---------------------------------------------------------------------------

/// One poll against the shared window: returns the fresh items, the next
/// watermark, and how many items the retention window evicted unseen.
fn poll(w: &Mutex<BoundedWindow<u64>>, watermark: usize) -> (Vec<u64>, usize, usize) {
    let g = w.lock().unwrap();
    assert_eq!(g.dropped() + g.len(), g.total(), "window accounting broke");
    let (fresh, next) = g.since(watermark);
    let missed = (next - watermark) - fresh.len();
    (fresh.to_vec(), next, missed)
}

/// The PR 4 protocol, model-checked: a poller that advances by the
/// *returned watermark* receives every report exactly once, no matter how
/// the publisher's pushes and the retention window's evictions interleave
/// with its polls — evicted reports are each counted missed exactly once.
#[test]
fn reports_since_is_exactly_once_across_eviction_gaps() {
    model(|| {
        let w = Arc::new(Mutex::new(BoundedWindow::<u64>::new(1)));
        let publisher = {
            let w = Arc::clone(&w);
            thread::spawn(move || {
                for v in 0..3 {
                    w.lock().unwrap().push(v);
                }
            })
        };
        let mut watermark = 0;
        let mut delivered: Vec<u64> = Vec::new();
        let mut missed = 0;
        for _ in 0..2 {
            let (fresh, next, gap) = poll(&w, watermark);
            delivered.extend(fresh);
            missed += gap;
            watermark = next;
        }
        publisher.join().unwrap();
        let (fresh, next, gap) = poll(&w, watermark);
        delivered.extend(fresh);
        missed += gap;
        watermark = next;
        assert_eq!(watermark, 3);
        assert_eq!(
            delivered.len() + missed,
            3,
            "every report delivered or counted missed: {delivered:?} + {missed}"
        );
        let mut unique = delivered.clone();
        unique.dedup();
        assert_eq!(unique, delivered, "duplicate delivery: {delivered:?}");
        let mut sorted = delivered.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, delivered, "reports delivered out of order");
    });
}

/// Seeded regression: the pre-PR-4 client protocol — advancing the
/// watermark by counting returned reports instead of using the returned
/// watermark — re-receives the window's tail after an eviction gap. The
/// explorer must find that schedule and fail the model; this proves the
/// checker would have caught the original bug.
#[test]
fn seeded_count_based_watermark_bug_is_caught() {
    let msg = model_fails(|| {
        let w = Arc::new(Mutex::new(BoundedWindow::<u64>::new(1)));
        let publisher = {
            let w = Arc::clone(&w);
            thread::spawn(move || {
                for v in 0..3 {
                    w.lock().unwrap().push(v);
                }
            })
        };
        let mut watermark = 0;
        let mut delivered: Vec<u64> = Vec::new();
        for _ in 0..2 {
            let (fresh, _next, _gap) = poll(&w, watermark);
            delivered.extend(fresh);
            // BUG under test (pre-PR-4): count only what was returned.
            watermark = delivered.len();
        }
        publisher.join().unwrap();
        let (fresh, _next, _gap) = poll(&w, watermark);
        delivered.extend(fresh);
        let mut unique = delivered.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), delivered.len(), "duplicate delivery: {delivered:?}");
    });
    assert!(msg.contains("duplicate delivery"), "wrong failure: {msg}");
}

// ---------------------------------------------------------------------------
// Cross-shard forward vs worker death (net.rs::deliver_forward)
// ---------------------------------------------------------------------------

/// A worker homed on shard A dies while shard B still holds work for it:
/// shard B's pre-encoded `Forward` batch races shard A's close of the
/// connection. [`deliver_forward`] must splice the batch into the worker's
/// output buffer XOR recycle it into the pool — never both (aliasing) and
/// never neither (leak) — and once the death is processed no bytes may sit
/// in any *live* buffer (the corpse's buffer dies with the connection, so
/// no frame is ever emitted to it). Shard B's own handling of the death
/// broadcast is guarded by route removal, keeping recovery exactly-once
/// even if the notification is observed twice.
#[test]
fn cross_shard_forward_vs_worker_death_conserves_buffers() {
    model(|| {
        // Shard A's connection table: conn 1 is the worker's output buffer.
        let conns: Arc<Mutex<HashMap<u64, Vec<u8>>>> = Arc::new(Mutex::new(HashMap::new()));
        let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
        conns.lock().unwrap().insert(1, Vec::new());
        let (fwd_tx, fwd_rx) = channel::<(u64, Vec<u8>)>();
        let recoveries = Arc::new(AtomicUsize::new(0));
        // Shard B: forward a coalesced batch toward the worker, then handle
        // the (possibly duplicated) WorkerDead broadcast — the route-removal
        // guard is what keeps the parked-assignment recovery exactly-once.
        let shard_b = {
            let pool = Arc::clone(&pool);
            let recoveries = Arc::clone(&recoveries);
            thread::spawn(move || {
                let mut batch = pool_get(&pool);
                batch.extend_from_slice(b"frame-bytes");
                let _ = fwd_tx.send((1, batch));
                let mut routes: HashMap<u32, ()> = HashMap::new();
                routes.insert(7, ());
                for _ in 0..2 {
                    if routes.remove(&7).is_some() {
                        recoveries.fetch_add(1, Ordering::SeqCst);
                    }
                }
            })
        };
        // Shard A's loop: a forward drain, the worker's death (buffer
        // discarded with the connection), a final drain. B's send lands in
        // any of the gaps between them.
        let mut delivered = 0usize;
        while let Ok((conn, bytes)) = fwd_rx.try_recv() {
            let mut g = conns.lock().unwrap();
            if deliver_forward(g.get_mut(&conn), bytes, &pool) {
                delivered += 1;
            }
        }
        let died = conns.lock().unwrap().remove(&1).is_some();
        shard_b.join().unwrap();
        while let Ok((conn, bytes)) = fwd_rx.try_recv() {
            let mut g = conns.lock().unwrap();
            if deliver_forward(g.get_mut(&conn), bytes, &pool) {
                delivered += 1;
            }
        }
        assert!(died);
        assert_eq!(recoveries.load(Ordering::SeqCst), 1, "recovery must run exactly once");
        assert!(delivered <= 1, "batch aliased: spliced {delivered} times");
        // Conservation: spliced or recycled, the batch's buffer is back in
        // the pool, and the corpse left no live buffer holding its bytes.
        assert_eq!(
            pool.lock().unwrap().len(),
            1,
            "batch buffer leaked (delivered={delivered})"
        );
        assert!(
            conns.lock().unwrap().is_empty(),
            "frame would be emitted to the dead worker's connection"
        );
    });
}

/// The conservation helper itself must round-trip: what `pool_put`
/// accepts, `pool_get` hands back (a sanity anchor for the model
/// above — if pooling silently dropped small buffers, the pool-length
/// assertion would undercount and the model would pass vacuously).
#[test]
fn pool_round_trips_small_buffers() {
    model(|| {
        let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
        pool_put(&pool, Vec::with_capacity(64));
        assert_eq!(pool.lock().unwrap().len(), 1);
    });
}

// ---------------------------------------------------------------------------
// Runtime global init (runtime/mod.rs::global)
// ---------------------------------------------------------------------------

/// The `Runtime::global` pattern with the init lock (PJRT client stubbed
/// by a construction counter): two racing first callers must construct
/// exactly once. Mirrors `runtime/mod.rs` — `GLOBAL` is the slot mutex,
/// `INIT` serializes construction.
#[test]
fn global_init_races_single_construction() {
    model(|| {
        let slot: Arc<Mutex<Option<u32>>> = Arc::new(Mutex::new(None));
        let init: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
        let ctors = Arc::new(AtomicUsize::new(0));
        let get = |slot: &Mutex<Option<u32>>, init: &Mutex<()>, ctors: &AtomicUsize| {
            if slot.lock().unwrap().is_some() {
                return;
            }
            let _init = init.lock().unwrap();
            let mut g = slot.lock().unwrap();
            if g.is_none() {
                ctors.fetch_add(1, Ordering::SeqCst);
                *g = Some(42);
            }
        };
        let racer = {
            let (slot, init, ctors) =
                (Arc::clone(&slot), Arc::clone(&init), Arc::clone(&ctors));
            thread::spawn(move || get(&slot, &init, &ctors))
        };
        get(&slot, &init, &ctors);
        racer.join().unwrap();
        assert_eq!(ctors.load(Ordering::SeqCst), 1, "PJRT client constructed twice");
        assert_eq!(*slot.lock().unwrap(), Some(42));
    });
}

/// Seeded regression: the pre-PR-6 `Runtime::global` — a bare
/// check-then-construct with no init lock — lets two first callers both
/// run `Runtime::new`. The explorer must find the double construction.
#[test]
fn seeded_naive_global_init_double_constructs() {
    let msg = model_fails(|| {
        let slot: Arc<Mutex<Option<u32>>> = Arc::new(Mutex::new(None));
        let ctors = Arc::new(AtomicUsize::new(0));
        // BUG under test: `if GLOBAL.get().is_none() { GLOBAL.set(new()?) }`.
        let get = |slot: &Mutex<Option<u32>>, ctors: &AtomicUsize| {
            let vacant = slot.lock().unwrap().is_none();
            if vacant {
                ctors.fetch_add(1, Ordering::SeqCst);
                let mut g = slot.lock().unwrap();
                if g.is_none() {
                    *g = Some(42);
                }
            }
        };
        let racer = {
            let (slot, ctors) = (Arc::clone(&slot), Arc::clone(&ctors));
            thread::spawn(move || get(&slot, &ctors))
        };
        get(&slot, &ctors);
        racer.join().unwrap();
        assert_eq!(ctors.load(Ordering::SeqCst), 1, "PJRT client constructed twice");
    });
    assert!(msg.contains("constructed twice"), "wrong failure: {msg}");
}

// ---------------------------------------------------------------------------
// Data-plane link pool (worker/dataplane.rs, PR 10)
// ---------------------------------------------------------------------------

/// Socket-free stand-in for a pooled peer link: `epoch` records the pool
/// generation observed when the "connection" was established.
struct L {
    addr: &'static str,
    epoch: u64,
}

fn l_addr(l: &L) -> &str {
    l.addr
}

/// Dead-link eviction racing the gather path's checkout → use → checkin
/// (`dataplane.rs::acquire` + the per-group checkin): under every schedule
/// a link whose generation snapshot predates the evict must be rejected at
/// checkin — a connection established before a peer was declared dead may
/// never be observable in the pool after the eviction completes.
#[test]
fn link_pool_checkin_vs_evict_never_resurrects_a_stale_link() {
    model(|| {
        let pool = Arc::new(LinkPool::new(4, l_addr));
        // Seed one idle link established at the current generation.
        let g0 = pool.generation("p");
        assert!(pool.checkin(g0, L { addr: "p", epoch: g0 }));
        let evictor = {
            let pool = Arc::clone(&pool);
            thread::spawn(move || pool.evict("p"))
        };
        // The fetch path: pooled checkout, else a fresh connect under a
        // generation snapshot taken *before* the connect.
        match pool.checkout("p") {
            Some((l, gen)) => {
                let _ = pool.checkin(gen, l);
            }
            None => {
                let gen = pool.generation("p");
                let _ = pool.checkin(gen, L { addr: "p", epoch: gen });
            }
        }
        evictor.join().unwrap();
        // Quiescent invariant: anything still pooled for this address was
        // established at the post-evict generation.
        let current = pool.generation("p");
        assert_eq!(current, 1, "exactly one evict must have bumped the generation");
        while let Some((l, _gen)) = pool.checkout("p") {
            assert_eq!(
                l.epoch, current,
                "a link from before the eviction survived in the pool"
            );
        }
        assert_eq!(pool.idle_len(), 0);
    });
}

/// Two peers' links racing into a capacity-1 pool: both checkins are
/// accepted (each observed a fresh generation) and the LRU admission
/// closes one, so the idle bound holds under every schedule.
#[test]
fn link_pool_capacity_bound_holds_under_racing_checkins() {
    model(|| {
        let pool = Arc::new(LinkPool::new(1, l_addr));
        let racer = {
            let pool = Arc::clone(&pool);
            thread::spawn(move || {
                let g = pool.generation("a");
                assert!(pool.checkin(g, L { addr: "a", epoch: g }));
            })
        };
        let g = pool.generation("b");
        assert!(pool.checkin(g, L { addr: "b", epoch: g }));
        racer.join().unwrap();
        assert_eq!(pool.idle_len(), 1, "LRU admission broke the pool bound");
    });
}
