//! Deliberately broken code that proves the UB/race CI jobs can go red.
//!
//! A checker that has only ever been observed green is indistinguishable
//! from a checker that is not running. Each job in the verification matrix
//! therefore has an inverted step: it compiles this file with the matching
//! `--cfg` and *fails the build if the tool does not report the planted
//! defect* (see .github/workflows/ci.yml and docs/verification.md).
//!
//!   - `--cfg rsds_seed_ub`:   a one-past-the-end raw read; Miri must
//!     refuse it with an out-of-bounds error.
//!   - `--cfg rsds_seed_race`: an unsynchronized cross-thread counter;
//!     ThreadSanitizer must report a data race.
//!
//! Under a normal `cargo test` neither cfg is set and this file compiles
//! to an empty test target, so tier-1 runs are unaffected.

#[cfg(rsds_seed_ub)]
#[test]
fn seeded_out_of_bounds_read() {
    let v = vec![1u8, 2, 3];
    let p = v.as_ptr();
    // SAFETY: none — this read is one past the end of the allocation. It
    // exists so the Miri job can demonstrate a red result; the CI step
    // inverts this test's exit status.
    let x = unsafe { *p.add(3) };
    assert!(x < u8::MAX, "never reached under Miri");
}

#[cfg(rsds_seed_race)]
#[test]
fn seeded_data_race() {
    use std::cell::UnsafeCell;

    struct Racy(UnsafeCell<u64>);
    // SAFETY: none — `UnsafeCell` is deliberately shared across threads
    // without synchronization so ThreadSanitizer has a race to report; the
    // CI step inverts this test's exit status.
    unsafe impl Sync for Racy {}

    static CELL: Racy = Racy(UnsafeCell::new(0));
    let threads: Vec<_> = (0..2)
        .map(|_| {
            std::thread::spawn(|| {
                for _ in 0..100_000 {
                    // SAFETY: none — this is the planted unsynchronized
                    // read-modify-write the sanitizer must flag.
                    unsafe { *CELL.0.get() += 1 };
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    // SAFETY: all writers joined above; this read is quiescent (the race
    // the job must catch already happened inside the loop).
    let total = unsafe { *CELL.0.get() };
    assert!(total <= 200_000);
}
