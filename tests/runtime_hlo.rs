//! Integration: the PJRT runtime loads the AOT artifacts and its numerics
//! match independent Rust recomputations of the kernel semantics.
//!
//! Requires `make artifacts` (skipped with a message otherwise — the
//! Makefile's `test` target builds them first).

#![cfg(not(loom))]

use rsds::runtime::{synth_f32, synth_tokens, Runtime, HASH_BUCKETS, HASH_TOKENS, REDUCE_COLS, REDUCE_ROWS, TRANSPOSE_N};

fn runtime() -> Option<std::sync::MutexGuard<'static, Runtime>> {
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_present(&dir) {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::global().expect("pjrt client").lock().unwrap())
}

#[test]
fn partition_reduce_matches_rust_oracle() {
    let Some(mut rt) = runtime() else { return };
    for seed in [0u64, 7, 123_456] {
        let out = rt.partition_reduce(seed).expect("execute");
        assert_eq!(out.len(), 2, "[sum, mean]");
        let n = (REDUCE_ROWS * REDUCE_COLS) as f64;
        // Artifact computes reduce(x - 0.5) — the xarray anomaly op.
        let expected_sum: f64 =
            synth_f32(REDUCE_ROWS * REDUCE_COLS, seed).iter().map(|&v| v as f64 - 0.5).sum();
        let got_sum = out[0] as f64;
        let got_mean = out[1] as f64;
        assert!(
            (got_sum - expected_sum).abs() < 0.5,
            "seed {seed}: sum {got_sum} vs {expected_sum}"
        );
        assert!(
            (got_mean - expected_sum / n).abs() < 1e-4,
            "seed {seed}: mean {got_mean} vs {}",
            expected_sum / n
        );
    }
}

#[test]
fn numpy_step_matches_rust_oracle() {
    let Some(mut rt) = runtime() else { return };
    let seed = 42u64;
    let out = rt.numpy_step(seed).expect("execute");
    assert_eq!(out.len(), 1, "[partial_sum]");
    // sum(x + x^T) = 2 * sum(x)
    let expected: f64 =
        2.0 * synth_f32(TRANSPOSE_N * TRANSPOSE_N, seed).iter().map(|&v| v as f64).sum::<f64>();
    let got = out[0] as f64;
    assert!((got - expected).abs() / expected.abs() < 1e-4, "{got} vs {expected}");
}

#[test]
fn feature_hash_matches_rust_oracle() {
    let Some(mut rt) = runtime() else { return };
    let seed = 9u64;
    let out = rt.feature_hash(seed).expect("execute");
    assert_eq!(out.len(), HASH_BUCKETS);
    // Recompute the multiply-shift histogram in Rust.
    const HASH_MULT: i32 = -1_640_531_527;
    let mut expected = vec![0f32; HASH_BUCKETS];
    for tok in synth_tokens(HASH_TOKENS, seed) {
        let h = (tok.wrapping_mul(HASH_MULT)) >> 16; // arithmetic shift
        let b = (h & (HASH_BUCKETS as i32 - 1)) as usize;
        expected[b] += 1.0;
    }
    assert_eq!(out, expected, "hash histogram mismatch");
    let total: f32 = out.iter().sum();
    assert_eq!(total, HASH_TOKENS as f32, "counts conserved");
}

#[test]
fn executables_are_cached_across_calls() {
    let Some(mut rt) = runtime() else { return };
    // Second call must not re-compile (observable as being fast); mostly a
    // smoke test that the cache path returns consistent results.
    let a = rt.partition_reduce(5).unwrap();
    let b = rt.partition_reduce(5).unwrap();
    assert_eq!(a, b);
}
