//! Property-based tests over random task graphs and random event
//! interleavings: scheduler invariants (every task assigned exactly once,
//! dependencies respected, nothing lost across steal races), simulator
//! conservation, and codec totality.

#![cfg(not(loom))]

use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::protocol::{Msg, RunId, TaskFinishedInfo, TaskInputLoc};
use rsds::scheduler::{self, Action, WorkerId, WorkerInfo};
use rsds::server::{fairness, Dest, Origin, Reactor, SchedulerPool};
use rsds::sim::{simulate, SimConfig};
use rsds::taskgraph::{GraphBuilder, Payload, TaskGraph, TaskId, TaskSpec};
use rsds::testing::{check, scaled_cases, PropConfig};
use rsds::util::Rng;
use std::collections::{HashMap, HashSet};

/// Random DAG: layered, with random fan-in, durations and sizes.
fn random_graph(rng: &mut Rng) -> TaskGraph {
    let n_layers = rng.range_usize(1, 6);
    let mut b = GraphBuilder::new();
    let mut prev_layer: Vec<TaskId> = Vec::new();
    let mut k = 0;
    for layer in 0..n_layers {
        let width = rng.range_usize(1, 12);
        let mut this_layer = Vec::with_capacity(width);
        for _ in 0..width {
            let mut inputs = Vec::new();
            if !prev_layer.is_empty() {
                let fan = rng.range_usize(0, prev_layer.len().min(4) + 1);
                let mut pool = prev_layer.clone();
                rng.shuffle(&mut pool);
                inputs.extend(pool.into_iter().take(fan));
            }
            let dur = rng.gen_range(5_000) + 1;
            let size = rng.gen_range(100_000) + 1;
            this_layer.push(b.add(format!("t{layer}-{k}"), inputs, dur, size, Payload::BusyWait));
            k += 1;
        }
        prev_layer = this_layer;
    }
    b.build("random").unwrap()
}

/// Drive a scheduler through a full random-graph execution with a random
/// (but dependency-correct) completion order and random steal outcomes.
/// Returns Err on any invariant violation.
fn drive_scheduler(sched_name: &str, rng: &mut Rng) -> Result<(), String> {
    let graph = random_graph(rng);
    let n_workers = rng.range_usize(1, 9) as u32;
    let mut s = scheduler::by_name(sched_name, rng.next_u64()).unwrap();
    for i in 0..n_workers {
        s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i / 4 });
    }
    s.graph_submitted(&graph);

    let mut assigned: HashMap<TaskId, WorkerId> = HashMap::new();
    let mut finished: HashSet<TaskId> = HashSet::new();
    let mut unfinished_deps: Vec<usize> =
        graph.tasks().iter().map(|t| t.inputs.len()).collect();
    let mut actions = Vec::new();
    s.tasks_ready(&graph.roots(), &mut actions);

    let mut pending_steals: Vec<(TaskId, WorkerId, WorkerId)> = Vec::new();
    let mut guard = 0;
    loop {
        guard += 1;
        if guard > 200_000 {
            return Err("scheduler failed to converge".into());
        }
        // Apply actions.
        for a in std::mem::take(&mut actions) {
            match a {
                Action::Assign(a) => {
                    if finished.contains(&a.task) {
                        return Err(format!("{} assigned after finishing", a.task));
                    }
                    if unfinished_deps[a.task.idx()] != 0 {
                        return Err(format!("{} assigned before deps done", a.task));
                    }
                    if assigned.insert(a.task, a.worker).is_some() {
                        return Err(format!("{} assigned twice", a.task));
                    }
                }
                Action::Steal { task, from, to } => {
                    if finished.contains(&task) {
                        // permitted: scheduler may lag; reactor rejects it
                        s.steal_result(task, from, to, false, &mut actions);
                        continue;
                    }
                    match assigned.get(&task) {
                        Some(&w) if w == from => pending_steals.push((task, from, to)),
                        other => {
                            return Err(format!(
                                "steal of {task} from {from} but assigned to {other:?}"
                            ))
                        }
                    }
                }
            }
        }
        if !actions.is_empty() {
            continue;
        }
        // Random event: resolve a steal or finish an assigned-ready task.
        let runnable: Vec<TaskId> = assigned
            .keys()
            .copied()
            .filter(|t| {
                !finished.contains(t) && !pending_steals.iter().any(|(pt, _, _)| pt == t)
            })
            .collect();
        let must_resolve = runnable.is_empty() && !pending_steals.is_empty();
        if must_resolve || (!pending_steals.is_empty() && rng.chance(0.4)) {
            let idx = rng.range_usize(0, pending_steals.len());
            let (task, from, to) = pending_steals.swap_remove(idx);
            let ok = rng.chance(0.6) && !finished.contains(&task);
            if ok {
                assigned.insert(task, to);
            }
            s.steal_result(task, from, to, ok, &mut actions);
            continue;
        }
        if runnable.is_empty() {
            break;
        }
        let task = *rng.choose(&runnable);
        let worker = assigned[&task];
        finished.insert(task);
        let mut newly_ready = Vec::new();
        for &c in graph.consumers(task) {
            unfinished_deps[c.idx()] -= 1;
            if unfinished_deps[c.idx()] == 0 {
                newly_ready.push(c);
            }
        }
        s.task_finished(task, worker, graph.task(task).output_size, graph.task(task).duration_us, &mut actions);
        if !newly_ready.is_empty() {
            let mut buf = Vec::new();
            s.tasks_ready(&newly_ready, &mut buf);
            actions.extend(buf);
        }
    }
    if finished.len() != graph.len() {
        return Err(format!(
            "only {}/{} tasks finished (assigned {})",
            finished.len(),
            graph.len(),
            assigned.len()
        ));
    }
    Ok(())
}

#[test]
fn prop_random_scheduler_invariants() {
    check("random scheduler", PropConfig { cases: 40, seed: 101 }, |rng| {
        drive_scheduler("random", rng)
    });
}

#[test]
fn prop_ws_scheduler_invariants() {
    check("ws scheduler", PropConfig { cases: 40, seed: 202 }, |rng| {
        drive_scheduler("ws", rng)
    });
}

#[test]
fn prop_dask_ws_scheduler_invariants() {
    check("dask-ws scheduler", PropConfig { cases: 40, seed: 303 }, |rng| {
        drive_scheduler("dask-ws", rng)
    });
}

/// Scheduler-model vs reactor-state queue parity for every live run in
/// `runs`: totals must always match; per-worker queue *sets* must match
/// whenever the run has no steal in flight. Shared by the interleaving and
/// fairness suites.
fn check_queue_parity(reactor: &Reactor, runs: &HashMap<RunId, u64>) -> Result<(), String> {
    for &run in runs.keys() {
        let (Some(gr), Some(sched)) = (reactor.run_state(run), reactor.scheduler_view(run))
        else {
            continue; // completed (or failed) — retired state is checked at the end
        };
        let Some(model_q) = sched.queued_tasks() else { continue };
        let reactor_q = gr.queued_by_worker();
        let model_total: usize = model_q.iter().map(|(_, q)| q.len()).sum();
        let reactor_total: usize = reactor_q.values().map(|q| q.len()).sum();
        if model_total != reactor_total {
            return Err(format!(
                "{run}: scheduler queues {model_total} tasks, reactor sees {reactor_total}"
            ));
        }
        if sched.in_flight_steal_count() == 0 {
            for (w, q) in &model_q {
                let empty = Vec::new();
                let rq = reactor_q.get(w).unwrap_or(&empty);
                if q != rq {
                    return Err(format!(
                        "{run}: at quiescence {w} queue mismatch: scheduler {q:?} vs reactor {rq:?}"
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Drive the multi-run reactor with randomized finish/steal interleavings
/// from model workers that defer execution arbitrarily; with
/// `max_kills > 0`, worker disconnects are additionally injected at random
/// points (never killing the last worker), exercising lineage recovery
/// against every race the interleaving can produce. Checks, after every
/// reactor interaction:
/// - each live run's scheduler cluster-model queue *totals* match the
///   reactor's `TaskState` view (always), and the per-worker queue *sets*
///   match whenever that run has no steal in flight;
/// - without kills no task is ever executed twice, and at the end every
///   task of every run executed (exactly once without kills, at least once
///   with them) and every run completed — recovery never fails a run.
fn drive_reactor_interleaved(
    sched_name: &str,
    rng: &mut Rng,
    max_kills: usize,
    replication: usize,
) -> Result<(), String> {
    let n_graphs = rng.range_usize(1, 4);
    let graphs: Vec<TaskGraph> = (0..n_graphs).map(|_| random_graph(rng)).collect();
    let min_workers = (max_kills + 1) as u32; // always ≥1 survivor
    let n_workers = rng.range_usize(min_workers as usize, min_workers as usize + 6) as u32;
    let pool = SchedulerPool::new(sched_name, rng.next_u64()).expect("known scheduler");
    let mut reactor = Reactor::new(pool, RuntimeProfile::rust(), false)
        .with_replication(replication, 1);

    let mut out: Vec<(Dest, Msg)> = Vec::new();
    for c in 0..n_graphs as u32 {
        reactor.on_message(
            Origin::Unregistered { conn: c as u64 },
            Msg::RegisterClient { name: format!("c{c}") },
            &mut out,
        );
    }
    for i in 0..n_workers {
        reactor.on_message(
            Origin::Unregistered { conn: 100 + i as u64 },
            Msg::RegisterWorker {
                name: format!("w{i}"),
                ncores: 1,
                node: i / 4,
                // Replica placement skips workers with no data address, so
                // the replication variants need real-looking ones.
                data_addr: if replication > 1 {
                    format!("10.9.0.{i}:9000")
                } else {
                    String::new()
                },
            },
            &mut out,
        );
    }
    out.clear();
    // Recover the worker index behind a replica-push target address.
    let addr_worker = |a: &str| -> usize {
        a.strip_prefix("10.9.0.")
            .and_then(|rest| rest.strip_suffix(":9000"))
            .and_then(|i| i.parse().ok())
            .expect("registered data address")
    };

    let mut expected: HashMap<RunId, u64> = HashMap::new();
    for (c, g) in graphs.iter().enumerate() {
        reactor.on_message(
            Origin::Client(c as u32),
            Msg::SubmitGraph { graph: g.clone(), scheduler: None, open: false },
            &mut out,
        );
    }

    // Model workers: FIFO inbox (like a TCP stream) + a local set of
    // queued-but-not-started tasks whose execution the test delays
    // arbitrarily — that delay is what generates every finish/steal race.
    let mut inboxes: Vec<Vec<Msg>> = vec![Vec::new(); n_workers as usize];
    let mut local_queue: Vec<HashSet<(RunId, TaskId)>> =
        vec![HashSet::new(); n_workers as usize];
    let mut executed: HashMap<(RunId, TaskId), u32> = HashMap::new();
    let mut done: HashMap<RunId, u64> = HashMap::new();
    let mut alive: Vec<bool> = vec![true; n_workers as usize];
    let mut kills_left = max_kills;
    // Replica-added confirmations park here and land at random points —
    // racing kills, steals, finishes and run completion (a late ack for a
    // completed or failed run must be ignored, not crash the reactor).
    let mut pending_acks: Vec<(usize, Msg)> = Vec::new();

    let mut guard = 0u32;
    loop {
        guard += 1;
        if guard > 200_000 {
            return Err("interleaving failed to converge".into());
        }
        // Emit parked worker-bound messages (run-fair dispatch parks them;
        // this harness drains eagerly — bounded pump rounds get their own
        // dedicated property below).
        reactor.drain(&mut out);
        for (dest, msg) in std::mem::take(&mut out) {
            match (dest, msg) {
                (Dest::Worker(w), msg) => {
                    if alive[w.idx()] {
                        inboxes[w.idx()].push(msg); // dead sockets eat messages
                    }
                }
                (_, Msg::GraphSubmitted { run, n_tasks }) => {
                    expected.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphDone { run, n_tasks, .. }) => {
                    done.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphFailed { reason, .. }) => {
                    return Err(format!("graph failed: {reason}"));
                }
                (d, m) => return Err(format!("unexpected {:?} to {d:?}", m.op())),
            }
        }
        // Occasionally kill a live worker (its socket closes: undelivered
        // messages vanish, queued work is lost, stored outputs evaporate).
        if kills_left > 0
            && alive.iter().filter(|a| **a).count() > 1
            && rng.chance(0.03)
        {
            let live: Vec<usize> = (0..alive.len()).filter(|&i| alive[i]).collect();
            let w = *rng.choose(&live);
            alive[w] = false;
            kills_left -= 1;
            inboxes[w].clear();
            local_queue[w].clear();
            pending_acks.retain(|&(t, _)| t != w); // dead peers confirm nothing
            reactor.on_disconnect(Origin::Worker(WorkerId(w as u32)), &mut out);
            check_queue_parity(&reactor, &expected)?;
            continue;
        }
        let deliverable: Vec<usize> = (0..inboxes.len())
            .filter(|&w| alive[w] && !inboxes[w].is_empty())
            .collect();
        let runnable: Vec<(usize, (RunId, TaskId))> = local_queue
            .iter()
            .enumerate()
            .filter(|&(w, _)| alive[w])
            .flat_map(|(w, q)| q.iter().map(move |&k| (w, k)))
            .collect();
        if deliverable.is_empty() && runnable.is_empty() && pending_acks.is_empty() {
            break;
        }
        // Randomly deliver a parked replica confirmation first.
        if !pending_acks.is_empty()
            && (rng.chance(0.3) || (deliverable.is_empty() && runnable.is_empty()))
        {
            let i = rng.gen_range(pending_acks.len() as u64) as usize;
            let (w, ack) = pending_acks.swap_remove(i);
            reactor.on_message(Origin::Worker(WorkerId(w as u32)), ack, &mut out);
            check_queue_parity(&reactor, &expected)?;
            continue;
        }
        // Randomly either deliver a worker's next message or execute one of
        // its queued tasks (execution can jump ahead of pending steals).
        let deliver = !deliverable.is_empty() && (runnable.is_empty() || rng.chance(0.55));
        if deliver {
            let w = *rng.choose(&deliverable);
            let msg = inboxes[w].remove(0);
            match msg {
                Msg::Welcome { .. } => {}
                Msg::ComputeTask { run, task, .. } => {
                    // With kills, a stale pre-recovery assignment can still
                    // be parked in the inbox when a resurrection re-assigns
                    // the task here; the real worker just queues the
                    // duplicate and finishes it twice (idempotent).
                    if !local_queue[w].insert((run, task)) && max_kills == 0 {
                        return Err(format!("{run}/{task} assigned to w{w} while queued"));
                    }
                }
                Msg::StealRequest { run, task } => {
                    let ok = local_queue[w].remove(&(run, task));
                    reactor.on_message(
                        Origin::Worker(WorkerId(w as u32)),
                        Msg::StealResponse { run, task, ok },
                        &mut out,
                    );
                    check_queue_parity(&reactor, &expected)?;
                }
                Msg::CancelCompute { run, task } => {
                    // Recovery pulled the task back; a copy may or may not
                    // still be queued here.
                    local_queue[w].remove(&(run, task));
                }
                Msg::ReplicateData { run, task, addrs } => {
                    // Push our copy to each target; the *receiving* peer
                    // confirms, later, at a random point in the schedule.
                    for a in &addrs {
                        let t = addr_worker(a);
                        if alive[t] {
                            pending_acks.push((t, Msg::ReplicaAdded { run, task }));
                        }
                    }
                }
                Msg::ReleaseRun { run } => {
                    // Without failures, exactly-once execution implies a
                    // released run has nothing queued anywhere. With kills,
                    // a recovery duplicate can legitimately still sit here
                    // (an early copy finished the task elsewhere); the real
                    // worker purges it on release — mirror that.
                    if max_kills == 0 {
                        if let Some(k) = local_queue[w].iter().find(|(r, _)| *r == run) {
                            return Err(format!("{run} released with {} still queued", k.1));
                        }
                    }
                    local_queue[w].retain(|&(r, _)| r != run);
                }
                other => return Err(format!("worker got {:?}", other.op())),
            }
        } else {
            let &(w, (run, task)) = rng.choose(&runnable);
            local_queue[w].remove(&(run, task));
            let n = executed.entry((run, task)).or_insert(0);
            *n += 1;
            if *n > 1 && max_kills == 0 {
                return Err(format!("{run}/{task} executed {n} times"));
            }
            reactor.on_message(
                Origin::Worker(WorkerId(w as u32)),
                Msg::TaskFinished(TaskFinishedInfo {
                    run,
                    task,
                    nbytes: 8,
                    duration_us: 1,
                }),
                &mut out,
            );
            check_queue_parity(&reactor, &expected)?;
        }
    }

    if expected.len() != n_graphs {
        return Err(format!("{} of {n_graphs} submissions acknowledged", expected.len()));
    }
    for (run, n_tasks) in &expected {
        if done.get(run) != Some(n_tasks) {
            return Err(format!("{run} did not complete with {n_tasks} tasks: {done:?}"));
        }
        let run_executed =
            executed.iter().filter(|((r, _), _)| r == run).map(|(_, &n)| n as u64).sum::<u64>();
        if max_kills == 0 && run_executed != *n_tasks {
            return Err(format!("{run}: executed {run_executed} of {n_tasks} tasks"));
        }
        if run_executed < *n_tasks {
            return Err(format!(
                "{run}: only {run_executed} of {n_tasks} tasks ever executed"
            ));
        }
    }
    if reactor.live_runs() != 0 {
        return Err(format!("{} runs left live after completion", reactor.live_runs()));
    }
    Ok(())
}

#[test]
fn prop_reactor_ws_interleavings_keep_models_in_sync() {
    check("reactor ws interleavings", PropConfig { cases: 30, seed: 707 }, |rng| {
        drive_reactor_interleaved("ws", rng, 0, 1)
    });
}

#[test]
fn prop_reactor_ws_lifo_interleavings_keep_models_in_sync() {
    check("reactor ws-lifo interleavings", PropConfig { cases: 20, seed: 808 }, |rng| {
        drive_reactor_interleaved("ws-lifo", rng, 0, 1)
    });
}

#[test]
fn prop_reactor_dask_ws_interleavings_keep_models_in_sync() {
    check("reactor dask-ws interleavings", PropConfig { cases: 20, seed: 909 }, |rng| {
        drive_reactor_interleaved("dask-ws", rng, 0, 1)
    });
}

#[test]
fn prop_reactor_random_interleavings_complete() {
    // The random scheduler keeps no cluster model; the property reduces to
    // completion + exactly-once execution under the same interleavings.
    check("reactor random interleavings", PropConfig { cases: 20, seed: 1010 }, |rng| {
        drive_reactor_interleaved("random", rng, 0, 1)
    });
}

// ---- disconnect recovery interleavings (PR 3 tentpole) ----

#[test]
fn prop_reactor_ws_survives_interleaved_disconnects() {
    // Worker kills injected at random points between finishes and steals:
    // scheduler-vs-reactor queue parity must hold through every recovery,
    // every run must complete, every task must execute at least once.
    check("reactor ws disconnects", PropConfig { cases: 25, seed: 1111 }, |rng| {
        drive_reactor_interleaved("ws", rng, 2, 1)
    });
}

#[test]
fn prop_reactor_dask_ws_survives_interleaved_disconnects() {
    check("reactor dask-ws disconnects", PropConfig { cases: 20, seed: 1212 }, |rng| {
        drive_reactor_interleaved("dask-ws", rng, 2, 1)
    });
}

#[test]
fn prop_reactor_random_survives_interleaved_disconnects() {
    check("reactor random disconnects", PropConfig { cases: 20, seed: 1313 }, |rng| {
        drive_reactor_interleaved("random", rng, 2, 1)
    });
}

// ---- replicated object store (PR 8 tentpole) ----

#[test]
fn prop_replication_preserves_exactly_once_execution() {
    // Replication on, no kills: replicate-data directives and their
    // randomly-timed replica-added confirmations must not perturb the
    // scheduling machinery — queue parity holds and every task still
    // executes exactly once.
    check("reactor ws replication", PropConfig { cases: 25, seed: 1414 }, |rng| {
        drive_reactor_interleaved("ws", rng, 0, 2)
    });
}

#[test]
fn prop_replicated_kills_keep_models_in_sync() {
    // The full PR 8 surface under random schedules: kills race replica
    // pushes, confirmations, steals and finishes. Parity and completion
    // must survive every interleaving — including acks from workers that
    // die before delivery and acks landing after their run completed.
    check("reactor ws replicated kills", PropConfig { cases: 25, seed: 1515 }, |rng| {
        drive_reactor_interleaved("ws", rng, 2, 2)
    });
}

#[test]
fn prop_replicated_kills_complete_under_random_scheduler() {
    check("reactor random replicated kills", PropConfig { cases: 20, seed: 1616 }, |rng| {
        let k = rng.range_usize(2, 4); // k ∈ {2, 3}
        drive_reactor_interleaved("random", rng, 2, k)
    });
}

// ---- incremental graph extensions (PR 9 tentpole) ----

/// Submit a random graph's base *open*, then graft the remaining batches
/// in at random points of the finish/steal schedule — including after the
/// base has fully finished (an open run must idle, not retire). Queue
/// parity holds after every reactor interaction, every task of the full
/// graph executes exactly once, and the run completes only after the
/// close.
fn drive_reactor_extensions(sched_name: &str, rng: &mut Rng) -> Result<(), String> {
    let graph = loop {
        let g = random_graph(rng);
        if g.len() >= 2 {
            break g;
        }
    };
    let n_batches = rng.range_usize(2, graph.len().min(6) + 1);
    let (base, exts) = rsds::graphgen::split_incremental(&graph, n_batches);
    let mut pending_exts: std::collections::VecDeque<Vec<TaskSpec>> = exts.into();
    let n_workers = rng.range_usize(1, 5) as u32;
    let pool = SchedulerPool::new(sched_name, rng.next_u64()).expect("known scheduler");
    let mut reactor = Reactor::new(pool, RuntimeProfile::rust(), false);
    let mut out: Vec<(Dest, Msg)> = Vec::new();
    reactor.on_message(
        Origin::Unregistered { conn: 0 },
        Msg::RegisterClient { name: "c0".into() },
        &mut out,
    );
    for i in 0..n_workers {
        reactor.on_message(
            Origin::Unregistered { conn: 100 + i as u64 },
            Msg::RegisterWorker {
                name: format!("w{i}"),
                ncores: 1,
                node: i / 4,
                data_addr: String::new(),
            },
            &mut out,
        );
    }
    out.clear();
    reactor.on_message(
        Origin::Client(0),
        Msg::SubmitGraph { graph: base, scheduler: None, open: true },
        &mut out,
    );
    let mut expected: HashMap<RunId, u64> = HashMap::new();
    let mut inboxes: Vec<Vec<Msg>> = vec![Vec::new(); n_workers as usize];
    let mut local_queue: Vec<HashSet<(RunId, TaskId)>> =
        vec![HashSet::new(); n_workers as usize];
    let mut executed: HashMap<(RunId, TaskId), u32> = HashMap::new();
    let mut done: HashMap<RunId, u64> = HashMap::new();
    let mut run_id: Option<RunId> = None;
    let mut guard = 0u32;
    loop {
        guard += 1;
        if guard > 200_000 {
            return Err("extension interleaving failed to converge".into());
        }
        reactor.drain(&mut out);
        for (dest, msg) in std::mem::take(&mut out) {
            match (dest, msg) {
                (Dest::Worker(w), msg) => inboxes[w.idx()].push(msg),
                (_, Msg::GraphSubmitted { run, n_tasks }) => {
                    // Base ack and every extension ack: the total grows.
                    run_id = Some(run);
                    expected.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphDone { run, n_tasks, .. }) => {
                    done.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphFailed { reason, .. }) => {
                    return Err(format!("graph failed: {reason}"));
                }
                (d, m) => return Err(format!("unexpected {:?} to {d:?}", m.op())),
            }
        }
        let deliverable: Vec<usize> =
            (0..inboxes.len()).filter(|&w| !inboxes[w].is_empty()).collect();
        let runnable: Vec<(usize, (RunId, TaskId))> = local_queue
            .iter()
            .enumerate()
            .flat_map(|(w, q)| q.iter().map(move |&k| (w, k)))
            .collect();
        let idle = deliverable.is_empty() && runnable.is_empty();
        // Graft the next batch at a random point; forced once nothing else
        // can make progress (that's the extend-after-base-finished case).
        if !pending_exts.is_empty() && (idle || rng.chance(0.1)) {
            let run = run_id.expect("base submission was acked");
            let tasks = pending_exts.pop_front().expect("nonempty");
            let last = pending_exts.is_empty();
            reactor.on_message(
                Origin::Client(0),
                Msg::SubmitExtend { run, tasks, last },
                &mut out,
            );
            check_queue_parity(&reactor, &expected)?;
            continue;
        }
        if idle {
            break;
        }
        let deliver = !deliverable.is_empty() && (runnable.is_empty() || rng.chance(0.55));
        if deliver {
            let w = *rng.choose(&deliverable);
            let msg = inboxes[w].remove(0);
            match msg {
                // Consumer-delta re-pins target stored outputs; these model
                // workers store nothing, so a pin is a no-op (exactly the
                // real worker's behavior for an already-evicted key).
                Msg::Welcome { .. } | Msg::PinData { .. } => {}
                Msg::ComputeTask { run, task, .. } => {
                    if !local_queue[w].insert((run, task)) {
                        return Err(format!("{run}/{task} assigned to w{w} while queued"));
                    }
                }
                Msg::StealRequest { run, task } => {
                    let ok = local_queue[w].remove(&(run, task));
                    reactor.on_message(
                        Origin::Worker(WorkerId(w as u32)),
                        Msg::StealResponse { run, task, ok },
                        &mut out,
                    );
                    check_queue_parity(&reactor, &expected)?;
                }
                Msg::ReleaseRun { run } => {
                    if let Some(k) = local_queue[w].iter().find(|(r, _)| *r == run) {
                        return Err(format!("{run} released with {} still queued", k.1));
                    }
                }
                other => return Err(format!("worker got {:?}", other.op())),
            }
        } else {
            let &(w, (run, task)) = rng.choose(&runnable);
            local_queue[w].remove(&(run, task));
            let n = executed.entry((run, task)).or_insert(0);
            *n += 1;
            if *n > 1 {
                return Err(format!("{run}/{task} executed {n} times"));
            }
            reactor.on_message(
                Origin::Worker(WorkerId(w as u32)),
                Msg::TaskFinished(TaskFinishedInfo { run, task, nbytes: 8, duration_us: 1 }),
                &mut out,
            );
            check_queue_parity(&reactor, &expected)?;
        }
    }
    let run = run_id.ok_or("base submission never acked")?;
    let want = graph.len() as u64;
    if expected.get(&run) != Some(&want) {
        return Err(format!("final acked total {:?}, want {want}", expected.get(&run)));
    }
    if done.get(&run) != Some(&want) {
        return Err(format!("run completed with {:?}, want {want} tasks", done.get(&run)));
    }
    if executed.len() as u64 != want || executed.values().any(|&n| n != 1) {
        return Err(format!("{} distinct tasks executed, want {want}", executed.len()));
    }
    if reactor.live_runs() != 0 {
        return Err(format!("{} runs left live after close + completion", reactor.live_runs()));
    }
    Ok(())
}

#[test]
fn prop_reactor_ws_extension_interleavings_keep_models_in_sync() {
    check("reactor ws extensions", PropConfig { cases: scaled_cases(25), seed: 1818 }, |rng| {
        drive_reactor_extensions("ws", rng)
    });
}

#[test]
fn prop_reactor_dask_ws_extension_interleavings_keep_models_in_sync() {
    check(
        "reactor dask-ws extensions",
        PropConfig { cases: scaled_cases(20), seed: 1919 },
        |rng| drive_reactor_extensions("dask-ws", rng),
    );
}

#[test]
fn prop_reactor_random_extension_interleavings_complete() {
    check(
        "reactor random extensions",
        PropConfig { cases: scaled_cases(20), seed: 2121 },
        |rng| drive_reactor_extensions("random", rng),
    );
}

#[test]
fn prop_store_matches_refcount_oracle() {
    // Random insert/consume/lookup/restore/release/spill sequences against
    // an in-memory oracle. After every step: entry count and per-key
    // refcounts match the model, refcounts never go below zero (the store
    // saturates and self-evicts at exactly zero), resident bytes respect
    // the budget after each rebalance, resident + spilled bytes conserve
    // the total live bytes, and every live key stays readable with the
    // exact bytes that were inserted.
    use rsds::worker::spill::{MemSpill, SpillBackend};
    use rsds::worker::store::{DataKey, Lookup, ObjectStore};
    use std::sync::Arc;

    struct ModelEntry {
        len: usize,
        fill: u8,
        consumers: Option<u32>,
    }

    check("store oracle", PropConfig { cases: scaled_cases(150), seed: 1717 }, |rng| {
        let limit = if rng.chance(0.7) { Some(rng.gen_range(200)) } else { None };
        let backend = Arc::new(MemSpill::new());
        let store = ObjectStore::new(limit, backend.clone());
        let mut model: HashMap<DataKey, ModelEntry> = HashMap::new();
        let mut released: HashSet<RunId> = HashSet::new();
        let rand_key = |rng: &mut Rng| -> DataKey {
            (RunId(rng.gen_range(3) as u32), TaskId(rng.gen_range(16) as u32))
        };
        let fill_of = |k: &DataKey| (k.0 .0 as u8) ^ ((k.1 .0 as u8) << 2) ^ 0x5A;

        let n_ops = rng.range_usize(20, 120);
        for step in 0..n_ops {
            match rng.gen_range(8) {
                0 | 1 | 2 => {
                    let k = rand_key(rng);
                    let len = rng.range_usize(0, 40);
                    let consumers = rng.gen_range(4) as u32;
                    let ok = store.insert(k, Arc::new(vec![fill_of(&k); len]), consumers);
                    let want = !released.contains(&k.0) && !model.contains_key(&k);
                    if ok != want {
                        return Err(format!("step {step}: insert {k:?} got {ok}, want {want}"));
                    }
                    if ok {
                        model.insert(
                            k,
                            ModelEntry {
                                len,
                                fill: fill_of(&k),
                                consumers: if consumers == 0 { None } else { Some(consumers) },
                            },
                        );
                    }
                    store.maybe_spill();
                }
                3 | 4 => {
                    let k = rand_key(rng);
                    let evicted = store.consume(&k);
                    let want = match model.get_mut(&k) {
                        Some(ModelEntry { consumers: Some(n), .. }) => {
                            *n = n.saturating_sub(1);
                            *n == 0
                        }
                        _ => false, // pinned or absent: no-op
                    };
                    if evicted != want {
                        return Err(format!(
                            "step {step}: consume {k:?} got {evicted}, want {want}"
                        ));
                    }
                    if want {
                        model.remove(&k);
                    }
                }
                5 | 6 => {
                    let k = rand_key(rng);
                    match (store.get(&k), model.get(&k)) {
                        (Lookup::Miss, None) => {}
                        (Lookup::Miss, Some(_)) => {
                            return Err(format!("step {step}: live key {k:?} lost"));
                        }
                        (Lookup::Hit(_) | Lookup::Spilled, None) => {
                            return Err(format!("step {step}: ghost entry {k:?}"));
                        }
                        (Lookup::Hit(b), Some(m)) => {
                            if b.as_ref() != &vec![m.fill; m.len] {
                                return Err(format!("step {step}: {k:?} bytes corrupted"));
                            }
                        }
                        (Lookup::Spilled, Some(m)) => {
                            let b = store
                                .restore(&k)
                                .ok_or_else(|| format!("step {step}: restore {k:?} failed"))?;
                            if b.as_ref() != &vec![m.fill; m.len] {
                                return Err(format!("step {step}: {k:?} torn on restore"));
                            }
                            store.maybe_spill();
                        }
                    }
                }
                _ => {
                    let run = RunId(rng.gen_range(3) as u32);
                    store.release_run(run);
                    released.insert(run);
                    model.retain(|k, _| k.0 != run);
                }
            }
            // Invariants after every operation.
            if store.num_entries() != model.len() {
                return Err(format!(
                    "step {step}: {} entries, model has {}",
                    store.num_entries(),
                    model.len()
                ));
            }
            if let Some(l) = limit {
                // Sequential driver: after the rebalance calls above, at
                // most one oversized entry can keep us above budget — and
                // only if *everything* else is already spilled. maybe_spill
                // always converges to ≤ limit unless a single entry alone
                // exceeds it and is the last resident one; even then it
                // spills. So the bound is exact here.
                if store.resident_bytes() > l {
                    return Err(format!(
                        "step {step}: resident {} exceeds budget {l}",
                        store.resident_bytes()
                    ));
                }
            }
            let live: u64 = model.values().map(|m| m.len as u64).sum();
            if store.resident_bytes() + backend.spilled_bytes() != live {
                return Err(format!(
                    "step {step}: resident {} + spilled {} != live {live}",
                    store.resident_bytes(),
                    backend.spilled_bytes()
                ));
            }
            if backend.misuse_count() != 0 {
                return Err(format!("step {step}: backend misuse (double free / bad slot)"));
            }
            // Slot leak check (PR 9): every live backend slot must belong
            // to a currently-spilled live key. Byte conservation alone
            // can't catch a leaked zero-length slot — e.g. the abandoned-
            // spill path forgetting to free the slot it wrote.
            let spilled_keys =
                model.keys().filter(|k| matches!(store.get(k), Lookup::Spilled)).count();
            if backend.live_slots() != spilled_keys {
                return Err(format!(
                    "step {step}: backend holds {} slots but {spilled_keys} live keys \
                     are spilled (slot leak)",
                    backend.live_slots()
                ));
            }
            for (k, m) in &model {
                if store.refcount(k) != Some(m.consumers) {
                    return Err(format!(
                        "step {step}: refcount of {k:?} diverged: {:?} vs {:?}",
                        store.refcount(k),
                        m.consumers
                    ));
                }
            }
        }
        // Final sweep: every live key readable with the right bytes, then a
        // total release leaves nothing behind — in memory or on the tier.
        let keys: Vec<DataKey> = model.keys().copied().collect();
        for k in keys {
            let m = &model[&k];
            let b = match store.get(&k) {
                Lookup::Hit(b) => b,
                Lookup::Spilled => {
                    store.restore(&k).ok_or_else(|| format!("final restore {k:?} failed"))?
                }
                Lookup::Miss => return Err(format!("final: live key {k:?} lost")),
            };
            if b.as_ref() != &vec![m.fill; m.len] {
                return Err(format!("final: {k:?} bytes corrupted"));
            }
        }
        for r in 0..3u32 {
            store.release_run(RunId(r));
        }
        if store.num_entries() != 0 || store.resident_bytes() != 0 {
            return Err("release left entries behind".into());
        }
        if backend.spilled_bytes() != 0 {
            return Err("release leaked spill slots".into());
        }
        if backend.live_slots() != 0 {
            return Err(format!("release leaked {} backend slots", backend.live_slots()));
        }
        if backend.misuse_count() != 0 {
            return Err("backend misuse during teardown".into());
        }
        Ok(())
    });
}

// ---- run-fair dispatch + admission control (PR 4 tentpole) ----

/// Drive a round-robin reactor over one large run plus K small runs with
/// random interleavings of pump rounds and worker events, asserting:
/// (a) bounded progress — every run with parked messages is serviced
/// within one full rotation (`live runs` pump rounds); (b) scheduler-model
/// vs reactor queue parity after every reactor interaction; (c) every run
/// completes.
fn drive_fairness_bounded_progress(rng: &mut Rng) -> Result<(), String> {
    let n_small = rng.range_usize(1, 4);
    let n_graphs = n_small + 1;
    let quota = rng.range_usize(1, 8);
    let n_workers = rng.range_usize(1, 5) as u32;
    let pool = SchedulerPool::new("ws", rng.next_u64()).expect("known scheduler");
    let mut reactor = Reactor::new(pool, RuntimeProfile::rust(), false)
        .with_fairness(fairness::by_name("rr").expect("rr is a policy"))
        .with_dispatch_quota(quota);
    let mut out: Vec<(Dest, Msg)> = Vec::new();
    for c in 0..n_graphs as u32 {
        reactor.on_message(
            Origin::Unregistered { conn: c as u64 },
            Msg::RegisterClient { name: format!("c{c}") },
            &mut out,
        );
    }
    for i in 0..n_workers {
        reactor.on_message(
            Origin::Unregistered { conn: 100 + i as u64 },
            Msg::RegisterWorker {
                name: format!("w{i}"),
                ncores: 1,
                node: 0,
                data_addr: String::new(),
            },
            &mut out,
        );
    }
    out.clear();
    let mut expected: HashMap<RunId, u64> = HashMap::new();
    // One large run first (the would-be starver), then the small ones.
    reactor.on_message(
        Origin::Client(0),
        Msg::SubmitGraph {
            graph: graphgen::merge(rng.range_usize(60, 200)),
            scheduler: None,
            open: false,
        },
        &mut out,
    );
    for c in 1..n_graphs as u32 {
        reactor.on_message(
            Origin::Client(c),
            Msg::SubmitGraph {
                graph: graphgen::merge(rng.range_usize(2, 9)),
                scheduler: None,
                open: false,
            },
            &mut out,
        );
    }
    let mut inboxes: HashMap<WorkerId, Vec<Msg>> = HashMap::new();
    let mut done: HashMap<RunId, u64> = HashMap::new();
    // Pump rounds each continuously-pending run has waited unserviced.
    let mut waited: HashMap<RunId, usize> = HashMap::new();
    let mut guard = 0u32;
    loop {
        guard += 1;
        if guard > 400_000 {
            return Err("fairness drive failed to converge".into());
        }
        for (dest, msg) in std::mem::take(&mut out) {
            match (dest, msg) {
                (Dest::Worker(w), msg) => inboxes.entry(w).or_default().push(msg),
                (_, Msg::GraphSubmitted { run, n_tasks }) => {
                    expected.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphDone { run, n_tasks, .. }) => {
                    done.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphFailed { reason, .. }) => {
                    return Err(format!("graph failed: {reason}"));
                }
                (d, m) => return Err(format!("unexpected {:?} to {d:?}", m.op())),
            }
        }
        let pending: Vec<RunId> = expected
            .keys()
            .filter(|&&run| {
                reactor.run_state(run).map(|g| !g.outbox.is_empty()).unwrap_or(false)
            })
            .copied()
            .collect();
        let deliverable: Vec<WorkerId> =
            inboxes.iter().filter(|(_, q)| !q.is_empty()).map(|(&w, _)| w).collect();
        if pending.is_empty() && deliverable.is_empty() {
            break;
        }
        let pump = !pending.is_empty() && (deliverable.is_empty() || rng.chance(0.5));
        if pump {
            let Some(serviced) = reactor.pump(&mut out) else {
                return Err("pump emitted nothing despite pending outboxes".into());
            };
            // Bounded progress: round-robin services every continuously-
            // pending run within one full rotation over the live runs.
            for &run in &pending {
                if run == serviced {
                    waited.insert(run, 0);
                } else {
                    let w = waited.entry(run).or_insert(0);
                    *w += 1;
                    if *w > n_graphs {
                        return Err(format!(
                            "{run} starved: {w} pump rounds without service \
                             ({n_graphs} live runs, quota {quota})"
                        ));
                    }
                }
            }
            // A run whose outbox drained leaves the rotation; it restarts
            // from zero if it re-fills later.
            waited.retain(|run, _| pending.contains(run));
        } else {
            let w = *rng.choose(&deliverable);
            let msg = inboxes.get_mut(&w).unwrap().remove(0);
            match msg {
                Msg::Welcome { .. } | Msg::ReleaseRun { .. } | Msg::CancelCompute { .. } => {}
                Msg::ComputeTask { run, task, output_size, .. } => {
                    reactor.on_message(
                        Origin::Worker(w),
                        Msg::TaskFinished(TaskFinishedInfo {
                            run,
                            task,
                            nbytes: output_size,
                            duration_us: 1,
                        }),
                        &mut out,
                    );
                    check_queue_parity(&reactor, &expected)?;
                }
                Msg::StealRequest { run, task } => {
                    reactor.on_message(
                        Origin::Worker(w),
                        Msg::StealResponse { run, task, ok: true },
                        &mut out,
                    );
                    check_queue_parity(&reactor, &expected)?;
                }
                other => return Err(format!("worker got {:?}", other.op())),
            }
        }
    }
    if done.len() != n_graphs {
        return Err(format!("{} of {n_graphs} runs completed: {done:?}", done.len()));
    }
    if reactor.pending_messages() != 0 {
        return Err(format!("{} messages still parked at quiescence", reactor.pending_messages()));
    }
    Ok(())
}

#[test]
fn prop_round_robin_pump_never_starves_a_run() {
    check(
        "rr bounded progress",
        PropConfig { cases: scaled_cases(25), seed: 1414 },
        drive_fairness_bounded_progress,
    );
}

/// One client pipelines more runs than its admission cap allows; random
/// delivery interleavings must activate every parked run and complete all
/// of them, with queue parity holding throughout.
fn drive_admission_interleaved(rng: &mut Rng) -> Result<(), String> {
    let n_graphs = rng.range_usize(2, 7);
    let cap = rng.range_usize(1, 3);
    let n_workers = rng.range_usize(1, 4) as u32;
    let pool = SchedulerPool::new("ws", rng.next_u64()).expect("known scheduler");
    let mut reactor =
        Reactor::new(pool, RuntimeProfile::rust(), false).with_admission_cap(cap);
    let mut out: Vec<(Dest, Msg)> = Vec::new();
    reactor.on_message(
        Origin::Unregistered { conn: 0 },
        Msg::RegisterClient { name: "c0".into() },
        &mut out,
    );
    for i in 0..n_workers {
        reactor.on_message(
            Origin::Unregistered { conn: 100 + i as u64 },
            Msg::RegisterWorker {
                name: format!("w{i}"),
                ncores: 1,
                node: 0,
                data_addr: String::new(),
            },
            &mut out,
        );
    }
    out.clear();
    let mut expected: HashMap<RunId, u64> = HashMap::new();
    let mut acked = 0usize;
    for _ in 0..n_graphs {
        reactor.on_message(
            Origin::Client(0),
            Msg::SubmitGraph {
                graph: graphgen::merge(rng.range_usize(2, 20)),
                scheduler: None,
                open: false,
            },
            &mut out,
        );
    }
    for (_, msg) in &out {
        match msg {
            Msg::GraphSubmitted { run, n_tasks } => {
                expected.insert(*run, *n_tasks);
                acked += 1;
            }
            Msg::RunQueued { .. } => acked += 1,
            _ => {}
        }
    }
    if acked != n_graphs {
        return Err(format!("{acked} of {n_graphs} submissions acked"));
    }
    if reactor.live_runs() != cap.min(n_graphs) {
        return Err(format!(
            "cap {cap}: {} live runs after {n_graphs} submissions",
            reactor.live_runs()
        ));
    }
    if reactor.queued_runs() != n_graphs.saturating_sub(cap) {
        return Err(format!("{} parked, expected {}", reactor.queued_runs(), n_graphs - cap));
    }
    let mut inboxes: HashMap<WorkerId, Vec<Msg>> = HashMap::new();
    let mut done: HashMap<RunId, u64> = HashMap::new();
    let mut guard = 0u32;
    loop {
        guard += 1;
        if guard > 400_000 {
            return Err("admission drive failed to converge".into());
        }
        reactor.drain(&mut out);
        for (dest, msg) in std::mem::take(&mut out) {
            match (dest, msg) {
                (Dest::Worker(w), msg) => inboxes.entry(w).or_default().push(msg),
                (_, Msg::GraphSubmitted { run, n_tasks }) => {
                    // Activation of a parked run.
                    expected.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::RunQueued { .. }) => {}
                (Dest::Client(_), Msg::GraphDone { run, n_tasks, .. }) => {
                    done.insert(run, n_tasks);
                }
                (Dest::Client(_), Msg::GraphFailed { reason, .. }) => {
                    return Err(format!("graph failed: {reason}"));
                }
                (d, m) => return Err(format!("unexpected {:?} to {d:?}", m.op())),
            }
        }
        if reactor.live_runs() > cap {
            return Err(format!(
                "admission cap {cap} violated: {} live runs",
                reactor.live_runs()
            ));
        }
        let deliverable: Vec<WorkerId> =
            inboxes.iter().filter(|(_, q)| !q.is_empty()).map(|(&w, _)| w).collect();
        if deliverable.is_empty() {
            if reactor.pending_messages() > 0 {
                continue; // drain next round
            }
            break;
        }
        let w = *rng.choose(&deliverable);
        let msg = inboxes.get_mut(&w).unwrap().remove(0);
        match msg {
            Msg::Welcome { .. } | Msg::ReleaseRun { .. } | Msg::CancelCompute { .. } => {}
            Msg::ComputeTask { run, task, output_size, .. } => {
                reactor.on_message(
                    Origin::Worker(w),
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 1,
                    }),
                    &mut out,
                );
                check_queue_parity(&reactor, &expected)?;
            }
            Msg::StealRequest { run, task } => {
                reactor.on_message(
                    Origin::Worker(w),
                    Msg::StealResponse { run, task, ok: rng.chance(0.7) },
                    &mut out,
                );
                check_queue_parity(&reactor, &expected)?;
            }
            other => return Err(format!("worker got {:?}", other.op())),
        }
    }
    if done.len() != n_graphs {
        return Err(format!(
            "{} of {n_graphs} runs completed (cap {cap}): {done:?}",
            done.len()
        ));
    }
    if reactor.queued_runs() != 0 || reactor.live_runs() != 0 {
        return Err(format!(
            "{} queued / {} live runs left after completion",
            reactor.queued_runs(),
            reactor.live_runs()
        ));
    }
    Ok(())
}

#[test]
fn prop_admission_queue_activates_everything() {
    check(
        "admission interleavings",
        PropConfig { cases: scaled_cases(25), seed: 1515 },
        drive_admission_interleaved,
    );
}

#[test]
fn prop_sim_conserves_tasks_and_respects_critical_path() {
    check("sim conservation", PropConfig { cases: 25, seed: 404 }, |rng| {
        let graph = random_graph(rng);
        let sched = *rng.choose(&["random", "ws", "dask-ws"]);
        let profile = if rng.chance(0.5) { RuntimeProfile::rust() } else { RuntimeProfile::python() };
        let cfg = SimConfig {
            n_workers: rng.range_usize(1, 50),
            seed: rng.next_u64(),
            ..SimConfig { profile, scheduler: sched.into(), ..SimConfig::default() }
        };
        let r = simulate(&graph, &cfg);
        if r.timed_out {
            return Err("random small graph timed out".into());
        }
        if r.n_tasks != graph.len() as u64 {
            return Err(format!("{} of {} tasks", r.n_tasks, graph.len()));
        }
        let cp = rsds::taskgraph::critical_path_us(&graph) as f64;
        if r.makespan_us < cp {
            return Err(format!("makespan {} beats critical path {cp}", r.makespan_us));
        }
        Ok(())
    });
}

#[test]
fn prop_graph_codec_roundtrips_random_graphs() {
    check("graph codec", PropConfig { cases: 40, seed: 505 }, |rng| {
        let g = random_graph(rng);
        let v = rsds::protocol::graph_to_value(&g);
        let back = rsds::protocol::graph_from_value(&v).map_err(|e| e.to_string())?;
        if back.len() != g.len() || back.n_deps() != g.n_deps() {
            return Err("structure mismatch after roundtrip".into());
        }
        for (a, b) in back.tasks().iter().zip(g.tasks()) {
            if a.inputs != b.inputs || a.duration_us != b.duration_us {
                return Err(format!("task {} mismatch", a.id));
            }
        }
        Ok(())
    });
}

// ---- codec equivalence: streaming vs Value tree (satellite: round-trip
// property tests + pull-parser fuzz) ----

fn rand_str(rng: &mut Rng, max: usize) -> String {
    let n = rng.range_usize(0, max);
    (0..n).map(|_| (b'a' + rng.gen_range(26) as u8) as char).collect()
}

fn random_payload(rng: &mut Rng) -> Payload {
    match rng.gen_range(7) {
        0 => Payload::NoOp,
        1 => Payload::BusyWait,
        2 => Payload::MergeInputs,
        3 => Payload::HloReduce {
            rows: rng.gen_range(1_000) as u32 + 1,
            cols: rng.gen_range(1_000) as u32 + 1,
            seed: rng.next_u64(),
        },
        4 => Payload::HloTranspose { n: rng.gen_range(512) as u32 + 1, seed: rng.next_u64() },
        5 => Payload::HloHash {
            n_tokens: rng.gen_range(10_000) as u32 + 1,
            buckets: rng.gen_range(4_096) as u32 + 1,
            seed: rng.next_u64(),
        },
        _ => Payload::WordBag { n_docs: rng.gen_range(1_000) as u32 + 1, seed: rng.next_u64() },
    }
}

/// One random message of every variant; integer fields span the full width
/// so every msgpack integer format boundary gets exercised.
fn random_msg(rng: &mut Rng) -> Msg {
    let run = RunId(rng.next_u64() as u32);
    let task = TaskId(rng.next_u64() as u32);
    // Bit-shifted magnitudes hit fixint / u8 / u16 / u32 / u64 encodings.
    let wide = |rng: &mut Rng| rng.next_u64() >> (rng.gen_range(64) as u32);
    match rng.gen_range(26) {
        0 => Msg::RegisterClient { name: rand_str(rng, 40) },
        1 => Msg::RegisterWorker {
            name: rand_str(rng, 40),
            ncores: rng.gen_range(128) as u32 + 1,
            node: rng.gen_range(64) as u32,
            data_addr: rand_str(rng, 24),
        },
        2 => Msg::Welcome { id: rng.next_u64() as u32 },
        3 => Msg::SubmitGraph {
            graph: random_graph(rng),
            scheduler: if rng.chance(0.5) { Some(rand_str(rng, 12)) } else { None },
            // False ~half the time: `open` is omitted on the wire when
            // false, so both shapes must round-trip.
            open: rng.chance(0.5),
        },
        4 => Msg::GraphSubmitted { run, n_tasks: wide(rng) },
        5 => Msg::GraphDone { run, makespan_us: wide(rng), n_tasks: wide(rng) },
        6 => Msg::GraphFailed { run, reason: rand_str(rng, 80) },
        7 => Msg::ReleaseRun { run },
        8 => {
            let n_inputs = rng.range_usize(0, 5);
            Msg::ComputeTask {
                run,
                task,
                key: rand_str(rng, 48),
                payload: random_payload(rng),
                duration_us: wide(rng),
                output_size: wide(rng),
                inputs: (0..n_inputs)
                    .map(|_| TaskInputLoc {
                        task: TaskId(rng.next_u64() as u32),
                        addr: rand_str(rng, 24),
                        // Empty ~half the time: the alts field is optional
                        // on the wire, so both shapes must round-trip.
                        alts: (0..rng.range_usize(0, 3)).map(|_| rand_str(rng, 24)).collect(),
                        nbytes: wide(rng),
                    })
                    .collect(),
                priority: rng.next_u64() as i64,
                // 0 (absent on the wire) ~quarter of the time.
                consumers: rng.gen_range(4) as u32,
                // 1 (absent on the wire) ~quarter of the time.
                cores: rng.gen_range(4) as u32 + 1,
            }
        }
        9 => Msg::TaskFinished(TaskFinishedInfo {
            run,
            task,
            nbytes: wide(rng),
            duration_us: wide(rng),
        }),
        10 => Msg::TaskErred { run, task, error: rand_str(rng, 60) },
        11 => Msg::StealRequest { run, task },
        12 => Msg::StealResponse { run, task, ok: rng.chance(0.5) },
        13 => Msg::FetchData { run, task },
        14 => Msg::FetchFromServer { run, task },
        17 => Msg::CancelCompute { run, task },
        15 => {
            let n = rng.range_usize(0, 400);
            Msg::DataReply { run, task, data: (0..n).map(|_| rng.next_u64() as u8).collect() }
        }
        16 => {
            let n = rng.range_usize(0, 400);
            Msg::DataToServer { run, task, data: (0..n).map(|_| rng.next_u64() as u8).collect() }
        }
        18 => Msg::RunQueued { run, position: wide(rng) },
        19 => Msg::ReplicateData {
            run,
            task,
            addrs: (0..rng.range_usize(0, 4)).map(|_| rand_str(rng, 24)).collect(),
        },
        20 => {
            let n = rng.range_usize(0, 400);
            Msg::PutData { run, task, data: (0..n).map(|_| rng.next_u64() as u8).collect() }
        }
        21 => Msg::ReplicaAdded { run, task },
        22 => Msg::ReplicaDropped { run, task },
        23 => {
            // Ids must be dense from `base`: the wire format carries only
            // the first id and the decoder re-derives the rest.
            let base = rng.gen_range(100_000) as u32 + 1;
            let n = rng.range_usize(0, 5);
            let tasks: Vec<TaskSpec> = (0..n as u32)
                .map(|i| TaskSpec {
                    id: TaskId(base + i),
                    key: rand_str(rng, 24),
                    inputs: (0..rng.range_usize(0, 4))
                        .map(|_| TaskId(rng.gen_range((base + i) as u64) as u32))
                        .collect(),
                    duration_us: wide(rng),
                    output_size: wide(rng),
                    payload: random_payload(rng),
                    // 1 (absent on the wire) ~half the time.
                    cores: rng.gen_range(2) as u32 * rng.gen_range(7) as u32 + 1,
                })
                .collect();
            Msg::SubmitExtend { run, tasks, last: rng.chance(0.5) }
        }
        24 => Msg::PinData { run, task, consumers: rng.gen_range(4) as u32 + 1 },
        _ => {
            if rng.chance(0.5) {
                Msg::Shutdown
            } else {
                Msg::Heartbeat
            }
        }
    }
}

#[test]
fn prop_streaming_codec_matches_value_tree_byte_for_byte() {
    use rsds::protocol::{decode_msg, decode_msg_value, encode_msg, encode_msg_value};
    check("codec byte identity", PropConfig { cases: 300, seed: 2020 }, |rng| {
        let m = random_msg(rng);
        let streamed = encode_msg(&m);
        let treed = encode_msg_value(&m);
        if streamed != treed {
            return Err(format!("byte mismatch for {:?}", m.op()));
        }
        let back = decode_msg(&streamed).map_err(|e| format!("{}: {e}", m.op()))?;
        if back != m {
            return Err(format!("streaming decode mismatch for {:?}", m.op()));
        }
        let back_tree = decode_msg_value(&streamed).map_err(|e| format!("{}: {e}", m.op()))?;
        if back_tree != m {
            return Err(format!("value-tree decode mismatch for {:?}", m.op()));
        }
        Ok(())
    });
}

#[test]
fn prop_codec_truncation_and_garbage_never_panic() {
    use rsds::protocol::decode_msg;
    check("codec fuzz", PropConfig { cases: 500, seed: 3030 }, |rng| {
        if rng.chance(0.5) {
            // Truncated valid message: a strict prefix must error cleanly.
            let m = random_msg(rng);
            let bytes = rsds::protocol::encode_msg(&m);
            let cut = rng.range_usize(0, bytes.len());
            if decode_msg(&bytes[..cut]).is_ok() {
                return Err(format!("truncated {} at {cut} decoded Ok", m.op()));
            }
        } else {
            // Random garbage: any result is fine, panicking is not.
            let n = rng.range_usize(0, 96);
            let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let _ = decode_msg(&bytes);
        }
        Ok(())
    });
}

#[test]
fn prop_generated_benchmarks_are_valid_dags() {
    // Every family, many parameter combinations: builder invariants hold
    // (no cycles — enforced by TaskGraph::new), sinks/roots sane.
    check("graphgen validity", PropConfig { cases: 30, seed: 606 }, |rng| {
        let spec = match rng.gen_range(8) {
            0 => format!("merge-{}", rng.gen_range(5_000) + 1),
            1 => format!("merge_slow-{}-{}ms", rng.gen_range(2_000) + 1, rng.gen_range(100) + 1),
            2 => format!("tree-{}", rng.gen_range(12) + 1),
            3 => format!("xarray-{}", rng.gen_range(40) + 2),
            4 => format!("bag-{}-{}", rng.gen_range(20_000) + 100, rng.gen_range(40) + 1),
            5 => format!("numpy-{}-{}", 1_000 + rng.gen_range(9_000), rng.gen_range(20) + 1),
            6 => format!("groupby-{}-1s-{}h", rng.gen_range(90) + 1, rng.gen_range(12) + 1),
            _ => format!("wordbag-{}-{}", rng.gen_range(5_000) + 100, rng.gen_range(60) + 1),
        };
        let g = graphgen::parse(&spec).map_err(|e| format!("{spec}: {e}"))?;
        if g.roots().is_empty() {
            return Err(format!("{spec}: no roots"));
        }
        if g.sinks().is_empty() {
            return Err(format!("{spec}: no sinks"));
        }
        if g.total_work_us() == 0 {
            return Err(format!("{spec}: zero total work"));
        }
        Ok(())
    });
}

// ---- interned per-task path (PR 5 tentpole) ----

/// Sink that checks, for every dispatched assignment, that the borrowed
/// encode is byte-identical to encoding the owned message — then forwards
/// the owned form so the drive loop can keep executing.
struct ByteCheckSink {
    msgs: Vec<(Dest, Msg)>,
    mismatches: usize,
    computes: usize,
}

impl rsds::server::OutboundSink for ByteCheckSink {
    fn emit_msg(&mut self, dest: Dest, msg: Msg) {
        self.msgs.push((dest, msg));
    }

    fn emit_compute(&mut self, d: &rsds::server::ComputeDispatch<'_>) {
        let owned = d.to_msg();
        let owned_bytes = rsds::protocol::encode_msg(&owned);
        let mut borrowed = Vec::new();
        d.encode_into(&mut borrowed);
        if borrowed != owned_bytes {
            self.mismatches += 1;
        }
        self.computes += 1;
        self.msgs.push((Dest::Worker(d.worker), owned));
    }
}

#[test]
fn prop_dispatch_byte_identity_over_random_graphs() {
    // Random graphs, random steal outcomes: every assignment the reactor
    // ever emits (first placement AND steal re-assignment) must encode
    // identically through the borrowed and owned paths.
    check(
        "dispatch byte identity",
        PropConfig { cases: scaled_cases(40), seed: 4242 },
        |rng| {
            let graph = random_graph(rng);
            let n_tasks = graph.len() as u64;
            let n_workers = rng.range_usize(1, 5) as u32;
            let mut r = Reactor::new(
                SchedulerPool::new("ws", rng.next_u64()).unwrap(),
                RuntimeProfile::rust(),
                false,
            );
            let mut out: Vec<(Dest, Msg)> = Vec::new();
            r.on_message(
                Origin::Unregistered { conn: 99 },
                Msg::RegisterClient { name: "c".into() },
                &mut out,
            );
            for i in 0..n_workers {
                r.on_message(
                    Origin::Unregistered { conn: i as u64 },
                    Msg::RegisterWorker {
                        name: format!("w{i}"),
                        ncores: 1,
                        node: 0,
                        data_addr: format!("10.0.0.{i}:9000"),
                    },
                    &mut out,
                );
            }
            out.clear();
            r.on_message(
                Origin::Client(0),
                Msg::SubmitGraph { graph, scheduler: None, open: false },
                &mut out,
            );
            let mut sink =
                ByteCheckSink { msgs: std::mem::take(&mut out), mismatches: 0, computes: 0 };
            let mut done = 0u64;
            let mut guard = 0u64;
            loop {
                guard += 1;
                if guard > 1_000_000 {
                    return Err("drive stuck".into());
                }
                r.drain_into(&mut sink);
                sink.msgs.append(&mut out);
                let Some((dest, msg)) = sink.msgs.pop() else { break };
                match (dest, msg) {
                    (Dest::Worker(w), Msg::ComputeTask { run, task, output_size, .. }) => {
                        r.on_message(
                            Origin::Worker(w),
                            Msg::TaskFinished(TaskFinishedInfo {
                                run,
                                task,
                                nbytes: output_size,
                                duration_us: 1,
                            }),
                            &mut out,
                        );
                    }
                    (Dest::Worker(w), Msg::StealRequest { run, task }) => {
                        r.on_message(
                            Origin::Worker(w),
                            Msg::StealResponse { run, task, ok: rng.chance(0.5) },
                            &mut out,
                        );
                    }
                    (_, Msg::GraphDone { n_tasks: n, .. }) => done = n,
                    (_, Msg::GraphFailed { reason, .. }) => {
                        return Err(format!("graph failed: {reason}"));
                    }
                    _ => {}
                }
            }
            if sink.mismatches != 0 {
                return Err(format!("{} byte mismatches", sink.mismatches));
            }
            if done != n_tasks {
                return Err(format!("completed {done}/{n_tasks} tasks"));
            }
            if sink.computes < graph_len_floor(n_tasks) {
                return Err(format!("only {} assignments dispatched", sink.computes));
            }
            Ok(())
        },
    );
}

/// Every task is assigned at least once, so the dispatched count can never
/// be below the task count.
fn graph_len_floor(n_tasks: u64) -> usize {
    n_tasks as usize
}

#[test]
fn prop_interned_queue_parity_with_owned_decode() {
    // The worker-side half: for random batches of compute-task frames,
    // the interned queue (borrowed view -> arenas -> pop) must observe
    // exactly the fields and ordering the owned decode implies.
    use rsds::protocol::ComputeTaskView;
    use rsds::worker::queue::{FetchPlan, TaskQueue};
    check(
        "interned queue parity",
        PropConfig { cases: scaled_cases(120), seed: 5151 },
        |rng| {
            let n = rng.range_usize(1, 40);
            let mut used: HashSet<(u32, u32)> = HashSet::new();
            let mut msgs: Vec<Msg> = Vec::new();
            for _ in 0..n {
                let run = rng.gen_range(3) as u32;
                let task = rng.gen_range(64) as u32;
                if !used.insert((run, task)) {
                    continue; // unique (run, task) per batch
                }
                let inputs: Vec<TaskInputLoc> = (0..rng.range_usize(0, 4))
                    .map(|j| TaskInputLoc {
                        task: TaskId(j as u32),
                        addr: if rng.chance(0.5) {
                            format!("10.0.{}.{}:9000", rng.gen_range(4), rng.gen_range(8))
                        } else {
                            String::new()
                        },
                        alts: (0..rng.range_usize(0, 3))
                            .map(|a| format!("10.1.{}.{a}:9000", rng.gen_range(8)))
                            .collect(),
                        nbytes: rng.next_u64() >> 40,
                    })
                    .collect();
                msgs.push(Msg::ComputeTask {
                    run: RunId(run),
                    task: TaskId(task),
                    key: format!("key-{run}-{task}"),
                    payload: Payload::BusyWait,
                    duration_us: rng.gen_range(100_000),
                    output_size: rng.gen_range(100_000),
                    inputs,
                    priority: (rng.gen_range(32) as i64) - 16, // dense: forces ties
                    consumers: rng.gen_range(4) as u32,
                    cores: rng.gen_range(4) as u32 + 1,
                });
            }
            // Truncation totality on the hot frame (any prefix errors).
            let first_bytes = rsds::protocol::encode_msg(&msgs[0]);
            for cut in 0..first_bytes.len() {
                if ComputeTaskView::decode(&first_bytes[..cut]).is_ok() {
                    return Err(format!("truncated view decode Ok at {cut}"));
                }
            }
            let mut q = TaskQueue::new();
            for m in &msgs {
                let bytes = rsds::protocol::encode_msg(m);
                let view = ComputeTaskView::decode(&bytes).map_err(|e| e.to_string())?;
                q.enqueue(&view).map_err(|e| e.to_string())?;
            }
            // Documented pop order: (priority, run, task) ascending.
            let mut expected: Vec<&Msg> = msgs.iter().collect();
            expected.sort_by_key(|m| match m {
                Msg::ComputeTask { priority, run, task, .. } => (*priority, run.0, task.0),
                _ => unreachable!(),
            });
            let mut plan = FetchPlan::new();
            for m in expected {
                let Msg::ComputeTask {
                    run,
                    task,
                    key,
                    payload,
                    duration_us,
                    output_size,
                    inputs,
                    priority,
                    consumers,
                    cores,
                } = m
                else {
                    unreachable!()
                };
                let p = q.pop_into(&mut plan).ok_or("queue drained early")?;
                if (p.run, p.task, p.priority) != (*run, *task, *priority) {
                    return Err(format!(
                        "pop order: got ({}, {}, {}), want ({run}, {task}, {priority})",
                        p.run, p.task, p.priority
                    ));
                }
                if plan.key() != key {
                    return Err(format!("key: got {:?}, want {key:?}", plan.key()));
                }
                if p.payload != *payload
                    || p.duration_us != *duration_us
                    || p.output_size != *output_size
                    || p.consumers != *consumers
                    || p.cores != *cores
                {
                    return Err(format!("scalar fields diverged for {run}/{task}"));
                }
                if plan.n_inputs() != inputs.len() {
                    return Err(format!(
                        "inputs: got {}, want {}",
                        plan.n_inputs(),
                        inputs.len()
                    ));
                }
                for (i, l) in inputs.iter().enumerate() {
                    if plan.input(i) != (l.task, l.nbytes, l.addr.as_str()) {
                        return Err(format!("input {i} diverged for {run}/{task}"));
                    }
                    if plan.n_alts(i) != l.alts.len() {
                        return Err(format!(
                            "input {i} alts: got {}, want {} for {run}/{task}",
                            plan.n_alts(i),
                            l.alts.len()
                        ));
                    }
                    for (a, alt) in l.alts.iter().enumerate() {
                        if plan.input_alt(i, a) != alt.as_str() {
                            return Err(format!("input {i} alt {a} diverged for {run}/{task}"));
                        }
                    }
                }
            }
            if q.pop_into(&mut plan).is_some() {
                return Err("queue had leftover tasks".into());
            }
            Ok(())
        },
    );
}

// ---- pooled worker↔worker gather (PR 10 tentpole) ----

/// Fake peer data server: serves `fetch-data` / `fetch-data-many` from a
/// fixed object map over real TCP, one thread per connection, mirroring
/// the real server's reply contract (in-order replies, connection close
/// on an unknown key).
fn spawn_data_peer(
    objects: HashMap<(RunId, TaskId), Vec<u8>>,
) -> String {
    use rsds::protocol::{decode_msg, FrameReader, FrameWriter};
    use std::net::TcpStream;

    fn reply(
        out: &mut FrameWriter,
        stream: &mut TcpStream,
        objects: &HashMap<(RunId, TaskId), Vec<u8>>,
        run: RunId,
        task: TaskId,
    ) -> bool {
        match objects.get(&(run, task)) {
            Some(d) => {
                out.send(stream, &Msg::DataReply { run, task, data: d.clone() }).is_ok()
            }
            None => false,
        }
    }

    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind peer");
    let addr = listener.local_addr().expect("peer addr").to_string();
    let objects = std::sync::Arc::new(objects);
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(mut stream) = conn else { break };
            let objects = objects.clone();
            std::thread::spawn(move || {
                let mut frames = FrameReader::new();
                let mut out = FrameWriter::new();
                loop {
                    let Ok(bytes) = frames.read(&mut stream) else { return };
                    let Ok(msg) = decode_msg(bytes) else { return };
                    match msg {
                        Msg::FetchData { run, task } => {
                            if !reply(&mut out, &mut stream, &objects, run, task) {
                                return;
                            }
                        }
                        Msg::FetchDataMany { run, tasks } => {
                            for task in tasks {
                                if !reply(&mut out, &mut stream, &objects, run, task) {
                                    return;
                                }
                            }
                        }
                        _ => return,
                    }
                }
            });
        }
    });
    addr
}

/// An address that refuses connections: bind an ephemeral port, then drop
/// the listener before anyone connects.
fn dead_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("bind dead");
    let a = l.local_addr().expect("dead addr").to_string();
    drop(l);
    a
}

#[test]
fn prop_gather_matches_sequential_baseline_and_consumes_exactly_once() {
    // Random gather scenarios over real TCP peers: inputs split between
    // pre-inserted locals, one not-yet-produced local (inserted by a racing
    // producer thread mid-gather), and remote objects spread over 1-3 fake
    // peers with randomly dead primaries/alts (connection-refused). With a
    // 25% chance one remote input has *only* dead sources.
    //
    // Properties, for both the pooled data plane and the sequential
    // connect-per-fetch baseline:
    // - every fully-reachable scenario completes with the exact expected
    //   bytes in plan order, and both modes agree on success and on the
    //   replica-dropped set (locals whose refcount hit zero; remote
    //   fetches are cached pinned and never dropped);
    // - every sabotaged scenario fails with a recoverable
    //   `fetch-failed:` error in both modes;
    // - a duplicate gather by the same consumer is exactly-once: it
    //   succeeds from cache, drops nothing, and leaves the refcounts of
    //   re-inserted and surviving entries untouched.
    use rsds::protocol::FETCH_FAILED_PREFIX;
    use rsds::worker::dataplane::{DataPlane, DataPlaneConfig, GatherScratch};
    use rsds::worker::queue::{FetchPlan, TaskQueue};
    use rsds::worker::spill::MemSpill;
    use rsds::worker::store::{Lookup, ObjectStore};
    use std::sync::Arc;
    use std::time::Duration;

    #[derive(Clone, PartialEq)]
    enum Kind {
        LocalPre { consumers: u32 },
        LocalDelayed,
        Remote { holder: usize, sabotaged: bool },
    }

    struct InputSpec {
        task: TaskId,
        bytes: Vec<u8>,
        kind: Kind,
    }

    check("dataplane gather", PropConfig { cases: scaled_cases(12), seed: 6262 }, |rng| {
        let run = RunId(1);
        let consumer = TaskId(1000);
        let n_peers = rng.range_usize(1, 4);
        let n_inputs = rng.range_usize(1, 9);

        // Generate input specs and the per-peer object maps.
        let mut specs: Vec<InputSpec> = Vec::new();
        let mut peer_objects: Vec<HashMap<(RunId, TaskId), Vec<u8>>> =
            vec![HashMap::new(); n_peers];
        let mut have_delayed = false;
        for i in 0..n_inputs as u32 {
            let task = TaskId(i);
            let len = rng.range_usize(1, 64);
            let bytes = vec![(7 + i) as u8; len];
            let kind = match rng.gen_range(4) {
                0 => Kind::LocalPre { consumers: rng.gen_range(2) as u32 + 1 },
                1 if !have_delayed => {
                    have_delayed = true;
                    Kind::LocalDelayed
                }
                _ => {
                    let holder = rng.range_usize(0, n_peers);
                    peer_objects[holder].insert((run, task), bytes.clone());
                    Kind::Remote { holder, sabotaged: false }
                }
            };
            specs.push(InputSpec { task, bytes, kind });
        }
        let sabotage = rng.chance(0.25)
            && specs.iter().any(|s| matches!(s.kind, Kind::Remote { .. }));
        if sabotage {
            // Sever every source of one remote input; delayed locals are
            // dropped from the scenario so the failure is deterministic.
            let victim = specs
                .iter()
                .position(|s| matches!(s.kind, Kind::Remote { .. }))
                .expect("a remote input exists");
            if let Kind::Remote { sabotaged, .. } = &mut specs[victim].kind {
                *sabotaged = true;
            }
            for s in &mut specs {
                if s.kind == Kind::LocalDelayed {
                    s.kind = Kind::LocalPre { consumers: 1 };
                }
            }
        }
        let peer_addrs: Vec<String> =
            peer_objects.into_iter().map(spawn_data_peer).collect();

        // Build the FetchPlan through the production enqueue/pop path.
        let msg = Msg::ComputeTask {
            run,
            task: consumer,
            key: "gather-prop".into(),
            payload: Payload::BusyWait,
            duration_us: 1,
            output_size: 8,
            inputs: specs
                .iter()
                .map(|s| {
                    let (addr, alts) = match s.kind {
                        Kind::LocalPre { .. } | Kind::LocalDelayed => (String::new(), vec![]),
                        Kind::Remote { sabotaged: true, .. } => {
                            (dead_addr(), vec![dead_addr()])
                        }
                        Kind::Remote { holder, sabotaged: false } => {
                            let live = peer_addrs[holder].clone();
                            if rng.chance(0.4) {
                                let mut alts = vec![live];
                                if rng.chance(0.3) {
                                    alts.push(dead_addr());
                                }
                                (dead_addr(), alts)
                            } else {
                                let alts =
                                    if rng.chance(0.3) { vec![dead_addr()] } else { vec![] };
                                (live, alts)
                            }
                        }
                    };
                    TaskInputLoc { task: s.task, addr, alts, nbytes: s.bytes.len() as u64 }
                })
                .collect(),
            priority: 0,
            consumers: 1,
            cores: 1,
        };
        let bytes = rsds::protocol::encode_msg(&msg);
        let view =
            rsds::protocol::ComputeTaskView::decode(&bytes).map_err(|e| e.to_string())?;
        let mut q = TaskQueue::new();
        q.enqueue(&view).map_err(|e| e.to_string())?;
        let mut plan = FetchPlan::new();
        let popped = q.pop_into(&mut plan).ok_or("queue drained early")?;

        let expected_dropped: Vec<TaskId> = {
            let mut d: Vec<TaskId> = specs
                .iter()
                .filter(|s| {
                    matches!(s.kind, Kind::LocalPre { consumers: 1 } | Kind::LocalDelayed)
                })
                .map(|s| s.task)
                .collect();
            d.sort();
            d
        };

        let mut outcomes: Vec<(bool, Vec<TaskId>)> = Vec::new();
        for pooled in [true, false] {
            let mode = if pooled { "pooled" } else { "baseline" };
            let plane = DataPlane::new(DataPlaneConfig {
                pooled,
                local_wait_ms: 2_000,
                ..DataPlaneConfig::default()
            });
            let store = Arc::new(ObjectStore::new(None, Arc::new(MemSpill::new())));
            let mut producer = None;
            for s in &specs {
                match s.kind {
                    Kind::LocalPre { consumers } => {
                        store.insert((run, s.task), Arc::new(s.bytes.clone()), consumers);
                    }
                    Kind::LocalDelayed => {
                        let st = store.clone();
                        let key = (run, s.task);
                        let data = s.bytes.clone();
                        producer = Some(std::thread::spawn(move || {
                            std::thread::sleep(Duration::from_millis(15));
                            st.insert(key, Arc::new(data), 1);
                        }));
                    }
                    Kind::Remote { .. } => {}
                }
            }
            let mut scratch = GatherScratch::new();
            let res = plane.gather(&store, popped.run, popped.task, &plan, &mut scratch);
            if let Some(p) = producer {
                p.join().map_err(|_| "producer thread panicked")?;
            }
            match &res {
                Ok(()) => {
                    if sabotage {
                        return Err(format!("{mode}: sabotaged gather succeeded"));
                    }
                    if scratch.inputs.len() != specs.len() {
                        return Err(format!(
                            "{mode}: {} inputs gathered, want {}",
                            scratch.inputs.len(),
                            specs.len()
                        ));
                    }
                    for (i, s) in specs.iter().enumerate() {
                        if scratch.inputs[i].as_ref() != &s.bytes {
                            return Err(format!("{mode}: input {i} bytes diverged"));
                        }
                    }
                    // Remote fetches must be cached passively (pinned).
                    for s in &specs {
                        if matches!(s.kind, Kind::Remote { sabotaged: false, .. }) {
                            if !matches!(store.get(&(run, s.task)), Lookup::Hit(_)) {
                                return Err(format!(
                                    "{mode}: fetched {} not cached",
                                    s.task
                                ));
                            }
                            if store.refcount(&(run, s.task)) != Some(None) {
                                return Err(format!(
                                    "{mode}: fetched {} cached unpinned",
                                    s.task
                                ));
                            }
                        }
                    }
                }
                Err(e) => {
                    if !e.starts_with(FETCH_FAILED_PREFIX) {
                        return Err(format!("{mode}: unrecoverable error: {e}"));
                    }
                    if !sabotage {
                        return Err(format!("{mode}: reachable gather failed: {e}"));
                    }
                }
            }
            let mut dropped = scratch.dropped.clone();
            dropped.sort();
            if res.is_ok() {
                if dropped != expected_dropped {
                    return Err(format!(
                        "{mode}: dropped {dropped:?}, want {expected_dropped:?}"
                    ));
                }
                // Exactly-once: re-insert what was dropped, gather again as
                // the same consumer. The duplicate must complete from cache
                // without decrementing anything.
                for t in &dropped {
                    let s = specs.iter().find(|s| s.task == *t).expect("dropped spec");
                    store.insert((run, *t), Arc::new(s.bytes.clone()), 1);
                }
                let mut scratch2 = GatherScratch::new();
                plane
                    .gather(&store, popped.run, popped.task, &plan, &mut scratch2)
                    .map_err(|e| format!("{mode}: duplicate gather failed: {e}"))?;
                if !scratch2.dropped.is_empty() {
                    return Err(format!(
                        "{mode}: duplicate gather dropped {:?}",
                        scratch2.dropped
                    ));
                }
                for (i, s) in specs.iter().enumerate() {
                    if scratch2.inputs[i].as_ref() != &s.bytes {
                        return Err(format!("{mode}: duplicate input {i} diverged"));
                    }
                }
                for t in &dropped {
                    if store.refcount(&(run, *t)) != Some(Some(1)) {
                        return Err(format!(
                            "{mode}: duplicate gather consumed re-inserted {t} again"
                        ));
                    }
                }
                for s in &specs {
                    if let Kind::LocalPre { consumers: 2 } = s.kind {
                        if store.refcount(&(run, s.task)) != Some(Some(1)) {
                            return Err(format!(
                                "{mode}: duplicate gather consumed surviving {} again",
                                s.task
                            ));
                        }
                    }
                }
            }
            outcomes.push((res.is_ok(), dropped));
        }
        if outcomes[0].0 != outcomes[1].0 {
            return Err(format!(
                "pooled ok={} but baseline ok={}",
                outcomes[0].0, outcomes[1].0
            ));
        }
        if outcomes[0].1 != outcomes[1].1 {
            return Err(format!(
                "dropped sets diverge: pooled {:?} vs baseline {:?}",
                outcomes[0].1, outcomes[1].1
            ));
        }
        Ok(())
    });
}
