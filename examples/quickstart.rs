//! Quickstart: start an in-process RSDS cluster (server + 4 workers),
//! run a tree reduction, print the result.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use rsds::client::Client;
use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::worker::{run_worker, WorkerConfig};

fn main() -> anyhow::Result<()> {
    // 1. Server with the RSDS work-stealing scheduler.
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 2020,
        profile: RuntimeProfile::rust(),
        emulate: false,
        ..ServerConfig::default()
    })?;
    println!("server on {}", srv.addr);

    // 2. Four single-core workers (the paper's per-core worker setting).
    let addr = srv.addr.to_string();
    let workers: Vec<_> = (0..4)
        .map(|i| {
            run_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("w{i}"),
                ncores: 1,
                node: 0,
                memory_limit: None,
                data_plane: Default::default(),
            })
        })
        .collect::<Result<_, _>>()?;
    println!("{} workers registered", workers.len());

    // 3. Submit a binary tree reduction of 2^10 numbers (1023 tasks).
    let graph = graphgen::tree(10);
    let mut client = Client::connect(&addr, "quickstart")?;
    let result = client.run_graph(&graph)?;

    println!(
        "{}: {} tasks in {:.1} ms  ({:.1} µs/task)",
        result.graph_name,
        result.n_tasks,
        result.makespan_us as f64 / 1e3,
        result.makespan_us as f64 / result.n_tasks as f64
    );

    for w in &workers {
        w.shutdown();
    }
    srv.shutdown();
    Ok(())
}
