//! End-to-end driver (the repo's full-stack proof): a real data pipeline on
//! a real local cluster, exercising every layer —
//!
//!   L1  Pallas kernels (partition_reduce / feature_hash, interpret-lowered)
//!   L2  JAX model fns → AOT HLO-text artifacts (`make artifacts`)
//!   RT  Rust PJRT runtime executing the artifacts inside workers
//!   L3  RSDS server (reactor + ws scheduler) over real TCP + msgpack
//!
//! Workload: the paper's xarray benchmark (chunked air-temperature
//! aggregation, §V) at partition size 25 — 550 real tasks whose array
//! payloads run the compiled Pallas kernels — plus a wordbag text pipeline.
//! The same graphs are then re-run against the Dask-emulation server
//! (calibrated CPython costs busy-waited on the hot path) to show the
//! paper's headline server-overhead effect on this machine.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use rsds::client::Client;
use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::runtime::Runtime;
use rsds::server::{serve, ServerConfig};
use rsds::taskgraph::{GraphStats, TaskGraph};
use rsds::worker::{run_worker, WorkerConfig};

struct RunOutcome {
    makespan_ms: f64,
    tasks_per_s: f64,
}

fn run_cluster(graphs: &[TaskGraph], emulate_python: bool, n_workers: u32) -> anyhow::Result<Vec<RunOutcome>> {
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: if emulate_python { "dask-ws".into() } else { "ws".into() },
        seed: 2020,
        profile: if emulate_python { RuntimeProfile::python() } else { RuntimeProfile::rust() },
        emulate: emulate_python,
        ..ServerConfig::default()
    })?;
    let addr = srv.addr.to_string();
    let workers: Vec<_> = (0..n_workers)
        .map(|i| {
            run_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("w{i}"),
                ncores: 1,
                node: i / 4,
                memory_limit: None,
                data_plane: Default::default(),
            })
        })
        .collect::<Result<_, _>>()?;
    let mut client = Client::connect(&addr, "e2e")?;
    let mut out = Vec::new();
    for graph in graphs {
        let res = client.run_graph(graph)?;
        out.push(RunOutcome {
            makespan_ms: res.makespan_us as f64 / 1e3,
            tasks_per_s: res.n_tasks as f64 / (res.makespan_us as f64 / 1e6),
        });
    }
    for w in &workers {
        w.shutdown();
    }
    srv.shutdown();
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    if !Runtime::artifacts_present(&Runtime::default_dir()) {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let n_workers = 8;

    // Real workloads: array pipeline (Pallas kernels via PJRT) + text
    // pipeline (Rust wordbag) + the scheduler stress test.
    let graphs = vec![graphgen::xarray(25), graphgen::wordbag(2_000, 40), graphgen::merge(5_000)];
    println!("== workloads ==");
    for g in &graphs {
        let s = GraphStats::of(g);
        println!(
            "  {:<18} {:>6} tasks {:>7} deps  LP {:>2}  needs_runtime={}",
            g.name,
            s.n_tasks,
            s.n_deps,
            s.longest_path,
            g.needs_runtime()
        );
    }

    println!("\n== RSDS server (rust profile, ws scheduler), {n_workers} workers ==");
    let rsds = run_cluster(&graphs, false, n_workers)?;
    for (g, r) in graphs.iter().zip(&rsds) {
        println!(
            "  {:<18} makespan {:>9.1} ms   throughput {:>9.0} tasks/s",
            g.name, r.makespan_ms, r.tasks_per_s
        );
    }

    println!("\n== Dask-emulation server (python profile busy-waited, dask-ws) ==");
    let dask = run_cluster(&graphs, true, n_workers)?;
    for (g, r) in graphs.iter().zip(&dask) {
        println!(
            "  {:<18} makespan {:>9.1} ms   throughput {:>9.0} tasks/s",
            g.name, r.makespan_ms, r.tasks_per_s
        );
    }

    println!("\n== headline: RSDS speedup over Dask-emulation (same graphs, same workers) ==");
    for (g, (r, d)) in graphs.iter().zip(rsds.iter().zip(&dask)) {
        println!("  {:<18} {:.2}×", g.name, d.makespan_ms / r.makespan_ms);
    }
    println!("\n(record these rows in EXPERIMENTS.md §E2E)");
    Ok(())
}
