//! Zero-worker overhead isolation on the REAL server (paper §VI-D): run
//! merge graphs against real TCP zero workers (§IV-D) and report the
//! average overhead per task (AOT) for the RSDS server and for the
//! Dask-emulation server, per scheduler.
//!
//! ```sh
//! cargo run --release --example zero_worker_overhead
//! ```

use rsds::client::Client;
use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::worker::zero::run_zero_worker;
use rsds::worker::WorkerConfig;

fn aot(scheduler: &str, emulate: bool, n_workers: u32, n_tasks: u32) -> anyhow::Result<f64> {
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: scheduler.into(),
        seed: 7,
        profile: if emulate { RuntimeProfile::python() } else { RuntimeProfile::rust() },
        emulate,
        ..ServerConfig::default()
    })?;
    let addr = srv.addr.to_string();
    let zws: Vec<_> = (0..n_workers)
        .map(|i| {
            run_zero_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("z{i}"),
                ncores: 1,
                node: i / 4,
                memory_limit: None,
                data_plane: Default::default(),
            })
        })
        .collect::<Result<_, _>>()?;
    let mut client = Client::connect(&addr, "aot")?;
    let res = client.run_graph(&graphgen::merge(n_tasks))?;
    for z in &zws {
        z.shutdown();
    }
    srv.shutdown();
    Ok(res.makespan_us as f64 / res.n_tasks as f64)
}

fn main() -> anyhow::Result<()> {
    let n_tasks = 5_000;
    println!("AOT (µs/task) for merge-{n_tasks} with real zero workers (§VI-D):\n");
    println!("{:>22} {:>10} {:>12}", "server/scheduler", "workers", "AOT µs/task");
    for workers in [4u32, 8, 16] {
        for (label, sched, emulate) in [
            ("rsds/ws", "ws", false),
            ("rsds/random", "random", false),
            ("dask-emu/ws", "dask-ws", true),
            ("dask-emu/random", "random", true),
        ] {
            let v = aot(sched, emulate, workers, n_tasks)?;
            println!("{label:>22} {workers:>10} {v:>12.1}");
        }
    }
    println!("\n(paper Fig 7/8: Dask ≈ 0.2–1 ms/task, RSDS well under 0.1 ms;");
    println!(" random's AOT stays flat as workers grow, work-stealing's rises.)");
    Ok(())
}
