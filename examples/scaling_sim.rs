//! Strong-scaling study (the paper's Fig 5) in the simulator: merge-100K,
//! groupby and merge_slow at 0.01/0.1/1 s task durations, 1–63 nodes,
//! RSDS vs Dask profiles.
//!
//! ```sh
//! cargo run --release --example scaling_sim            # full sweep
//! cargo run --release --example scaling_sim -- --quick # 3 cluster sizes
//! ```

use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate, SimConfig};
use rsds::util::stats::fmt_us;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let nodes: &[usize] = if quick { &[1, 7, 31] } else { &[1, 3, 7, 15, 23, 31, 47, 63] };

    let graphs = vec![
        graphgen::merge(100_000),
        graphgen::parse("groupby-2880-16s-16h").unwrap(),
        graphgen::merge_slow(20_000, 10_000),
        graphgen::merge_slow(20_000, 100_000),
        graphgen::merge_slow(20_000, 1_000_000),
    ];

    for graph in &graphs {
        println!("\n== {} (strong scaling, 24 workers/node) ==", graph.name);
        println!("{:>6} {:>9} {:>14} {:>14} {:>9}", "nodes", "workers", "rsds/ws", "dask/ws", "speedup");
        for &n in nodes {
            let rsds = simulate(graph, &SimConfig::nodes(n, RuntimeProfile::rust(), "ws"));
            let dask = simulate(graph, &SimConfig::nodes(n, RuntimeProfile::python(), "dask-ws"));
            println!(
                "{:>6} {:>9} {:>14} {:>14} {:>8.2}×{}",
                n,
                n * 24,
                fmt_us(rsds.makespan_us),
                fmt_us(dask.makespan_us),
                dask.makespan_us / rsds.makespan_us,
                if rsds.timed_out || dask.timed_out { "  (timeout)" } else { "" }
            );
        }
    }
    println!("\n(the paper's Fig 5 shapes: RSDS plateaus near 15 nodes on merge-100K,");
    println!(" Dask degrades with every added node, and 1 s tasks equalize both.)");
    Ok(())
}
