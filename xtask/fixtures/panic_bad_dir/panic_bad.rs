// Seeded violation (no-panic rule): one bare unwrap and one panic! in
// production position. The mutex-poisoning line and the test module are
// exemptions and must not be flagged — the self-check asserts exactly two
// findings.

pub fn seeded(v: Option<u32>, m: &std::sync::Mutex<u32>) -> u32 {
    let n = v.unwrap();
    let held = *m.lock().unwrap();
    if n > held {
        panic!("seeded panic");
    }
    n
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(1u32).unwrap(), 1);
    }
}
