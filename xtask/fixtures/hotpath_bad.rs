// Seeded violation for `cargo xtask lint --self-check` (hotpath rule).
// Never compiled; every allocation below must be reported when this file
// is registered through `xtask/fixtures/hotpath.txt`.

pub fn seeded_hot_alloc(key: &str) -> String {
    let copy = key.to_owned();
    let boxed = Box::new(copy.clone());
    format!("hot path allocated: {boxed}")
}
