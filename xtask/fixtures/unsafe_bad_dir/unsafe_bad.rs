// Seeded violation (safety-comment rule): two undocumented `unsafe`s.
// The documented impl at the bottom must NOT be reported — the self-check
// asserts exactly two findings, so a false positive fails it too.

pub struct Handle(*mut u8);

unsafe impl Send for Handle {}

pub fn peek(h: &Handle) -> u8 {
    unsafe { *h.0 }
}

// SAFETY: fixture stand-in for a real invariant argument.
unsafe impl Sync for Handle {}
