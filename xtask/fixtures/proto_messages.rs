// Seeded violation (protocol-ops rule): `ghost-op` has no codec literal
// and no doc-table row; see proto_codec.rs / proto_protocol.md.

impl Msg {
    pub fn op(&self) -> &'static str {
        match self {
            Msg::Real { .. } => "real-op",
            Msg::Ghost { .. } => "ghost-op",
        }
    }
}
