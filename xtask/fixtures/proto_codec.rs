// Codec side of the protocol-ops fixture: decodes `real-op` only —
// `ghost-op` is the seeded missing decode arm — and compares peek_op
// against `typo-op`, an op nobody defines.

pub fn decode_msg(bytes: &[u8]) -> Result<Msg, CodecError> {
    match find_op(bytes)? {
        "real-op" => decode_real(bytes),
        _ => Err(CodecError::UnknownOp),
    }
}

pub fn route(bytes: &[u8]) -> bool {
    matches!(peek_op(bytes), Ok("typo-op"))
}
