//! The four invariant checks behind `cargo xtask lint`.
//!
//! Each rule is a function over explicit paths so that `--self-check` can
//! re-point it at the seeded-violation fixtures in `xtask/fixtures/` and
//! prove the rule actually fires (a checker that has never been seen red
//! is not evidence of anything — see docs/verification.md).
//!
//! 1. [`check_hotpath`] — no allocating calls inside the functions
//!    registered in `xtask/hotpath.txt` (the zero-alloc control plane the
//!    counting-allocator bench measures end-to-end; the lint covers every
//!    build, not just the bench graph shapes).
//! 2. [`check_protocol_ops`] — protocol op strings stay consistent across
//!    `Msg::op()`, the codec, `peek_op` call sites, and the op tables in
//!    `docs/protocol.md`.
//! 3. [`check_safety`] — every `unsafe` carries a `SAFETY:` comment.
//! 4. [`check_no_panic`] — no `unwrap`/`expect`/`panic!` family calls in
//!    non-test `server/`, `worker/`, `protocol/` code, modulo the mutex
//!    poisoning idiom and a reviewed allowlist.

use crate::scan::{self, Source};
use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

pub struct Violation {
    pub path: PathBuf,
    /// 1-based; 0 for whole-file findings.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path.display(), self.line, self.rule, self.msg)
    }
}

pub type RuleResult = Result<Vec<Violation>, String>;

fn read(path: &Path) -> Result<String, String> {
    std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))
}

fn scan_file(path: &Path) -> Result<Source, String> {
    scan::scan(path).map_err(|e| format!("{}: {e}", path.display()))
}

// ---------------------------------------------------------------- rule 1

/// Allocating calls that must not appear in a registered hot function.
/// Matched against the code channel, so comments and string literals never
/// trigger.
const BANNED_ALLOC: &[&str] = &[
    "format!",
    ".to_owned()",
    ".to_string()",
    ".to_vec()",
    "String::from(",
    "String::new(",
    "Vec::new(",
    "Vec::with_capacity(",
    "vec![",
    "Box::new(",
    ".collect(",
];

/// A `.clone()` in a hot function is allowed only with an explicit
/// same-line or previous-line `lint: clone-ok` marker (used for clones of
/// plain scalar enums, which are memcpys).
const CLONE_OK: &str = "lint: clone-ok";

pub fn check_hotpath(repo: &Path, registry: &Path) -> RuleResult {
    let mut out = Vec::new();
    let reg = read(registry)?;
    for entry in reg.lines().map(str::trim).filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let Some((rel, fn_name)) = entry.rsplit_once("::") else {
            out.push(Violation {
                path: registry.to_path_buf(),
                line: 0,
                rule: "hotpath",
                msg: format!("malformed registry entry `{entry}` (want path.rs::fn_name)"),
            });
            continue;
        };
        let src = scan_file(&repo.join(rel))?;
        let Some((start, end)) = scan::fn_def(&src, fn_name) else {
            out.push(Violation {
                path: src.path.clone(),
                line: 0,
                rule: "hotpath",
                msg: format!("registered hot function `{fn_name}` not found"),
            });
            continue;
        };
        for (li, line) in src.lines.iter().enumerate().take(end + 1).skip(start) {
            let code = &line.code;
            for tok in BANNED_ALLOC {
                if code.contains(tok) {
                    out.push(Violation {
                        path: src.path.clone(),
                        line: li + 1,
                        rule: "hotpath",
                        msg: format!("`{tok}` allocates inside hot function `{fn_name}`"),
                    });
                }
            }
            if code.contains(".clone()") {
                let marked = src.raw[li].contains(CLONE_OK)
                    || (li > 0 && src.raw[li - 1].contains(CLONE_OK));
                if !marked {
                    out.push(Violation {
                        path: src.path.clone(),
                        line: li + 1,
                        rule: "hotpath",
                        msg: format!(
                            "`.clone()` inside hot function `{fn_name}` \
                             (mark scalar clones with `// {CLONE_OK}`)"
                        ),
                    });
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------- rule 2

fn looks_like_op(s: &str) -> bool {
    s.contains('-') && !s.is_empty() && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
}

fn first_backticked(cell: &str) -> Option<String> {
    let open = cell.find('`')?;
    let rest = &cell[open + 1..];
    let close = rest.find('`')?;
    Some(rest[..close].to_string())
}

/// `(line, op)` pairs from markdown tables whose header's first column is
/// `op`. Handles decorated cells like `` `submit-graph` (cold) ``.
fn doc_table_ops(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut in_op_table = false;
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        if !t.starts_with('|') {
            in_op_table = false;
            continue;
        }
        let first = t.trim_matches('|').split('|').next().unwrap_or("").trim();
        if first == "op" {
            in_op_table = true;
            continue;
        }
        if first.chars().all(|c| c == '-' || c == ' ' || c == ':') {
            continue; // separator row
        }
        if in_op_table {
            if let Some(op) = first_backticked(first) {
                out.push((i + 1, op));
            }
        }
    }
    out
}

pub fn check_protocol_ops(
    messages: &Path,
    codec: &Path,
    doc: &Path,
    rust_root: &Path,
) -> RuleResult {
    let mut out = Vec::new();

    // Source of truth: the string literals in `Msg::op()`.
    let msrc = scan_file(messages)?;
    let (start, end) = scan::fn_def(&msrc, "op")
        .ok_or_else(|| format!("{}: fn op not found", messages.display()))?;
    let mut ops: Vec<(usize, String)> = Vec::new();
    for (li, line) in msrc.lines.iter().enumerate().take(end + 1).skip(start) {
        for s in &line.strings {
            ops.push((li + 1, s.clone()));
        }
    }
    let op_set: BTreeSet<&str> = ops.iter().map(|(_, s)| s.as_str()).collect();
    for (li, op) in &ops {
        if ops.iter().filter(|(_, o)| o == op).count() > 1 {
            out.push(Violation {
                path: msrc.path.clone(),
                line: *li,
                rule: "protocol-ops",
                msg: format!("op string `{op}` returned for more than one message variant"),
            });
        }
    }

    // Every op must appear as a literal in the codec (a variant whose op
    // never shows up there has no decode arm).
    let csrc = scan_file(codec)?;
    let codec_strings: BTreeSet<&str> = csrc
        .lines
        .iter()
        .flat_map(|l| l.strings.iter().map(String::as_str))
        .collect();
    for (li, op) in &ops {
        if !codec_strings.contains(op.as_str()) {
            out.push(Violation {
                path: msrc.path.clone(),
                line: *li,
                rule: "protocol-ops",
                msg: format!(
                    "op `{op}` never appears in {} (missing decode arm?)",
                    codec.display()
                ),
            });
        }
    }

    // Doc tables: both directions.
    let doc_text = read(doc)?;
    let doc_ops = doc_table_ops(&doc_text);
    let doc_set: BTreeSet<&str> = doc_ops.iter().map(|(_, s)| s.as_str()).collect();
    for (li, op) in &ops {
        if !doc_set.contains(op.as_str()) {
            out.push(Violation {
                path: msrc.path.clone(),
                line: *li,
                rule: "protocol-ops",
                msg: format!("op `{op}` missing from the op tables in {}", doc.display()),
            });
        }
    }
    for (li, op) in &doc_ops {
        if !op_set.contains(op.as_str()) {
            out.push(Violation {
                path: doc.to_path_buf(),
                line: *li,
                rule: "protocol-ops",
                msg: format!("documented op `{op}` is not returned by Msg::op()"),
            });
        }
    }

    // peek_op call sites: a literal compared against the peeked op must be
    // a real op (catches silently-dead hot-path dispatch branches).
    for file in scan::rust_files(rust_root).map_err(|e| e.to_string())? {
        let src = scan_file(&file)?;
        for (li, line) in src.lines.iter().enumerate() {
            if !line.code.contains("peek_op(") {
                continue;
            }
            for s in &line.strings {
                if looks_like_op(s) && !op_set.contains(s.as_str()) {
                    out.push(Violation {
                        path: src.path.clone(),
                        line: li + 1,
                        rule: "protocol-ops",
                        msg: format!("peek_op compared against unknown op `{s}`"),
                    });
                }
            }
        }
    }

    Ok(out)
}

// ---------------------------------------------------------------- rule 3

fn check_safety_source(src: &Source, out: &mut Vec<Violation>) {
    for (li, line) in src.lines.iter().enumerate() {
        if scan::find_word(&line.code, "unsafe").is_none() {
            continue;
        }
        let mut ok = line.comment.contains("SAFETY:");
        let mut j = li;
        while !ok && j > 0 {
            j -= 1;
            let prev = &src.lines[j];
            if !prev.code.trim().is_empty() {
                break; // hit real code: the comment block ended
            }
            if prev.comment.contains("SAFETY:") {
                ok = true;
            }
            if prev.comment.is_empty() && prev.code.trim().is_empty() && src.raw[j].trim().is_empty()
            {
                break; // blank line ends the contiguous comment block
            }
        }
        if !ok {
            out.push(Violation {
                path: src.path.clone(),
                line: li + 1,
                rule: "safety-comment",
                msg: "`unsafe` without a `// SAFETY:` comment immediately above".to_string(),
            });
        }
    }
}

pub fn check_safety(rust_root: &Path) -> RuleResult {
    let mut out = Vec::new();
    for file in scan::rust_files(rust_root).map_err(|e| e.to_string())? {
        let src = scan_file(&file)?;
        check_safety_source(&src, &mut out);
    }
    Ok(out)
}

// ---------------------------------------------------------------- rule 4

const BANNED_PANIC: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

struct AllowEntry {
    path_suffix: String,
    needle: String,
    used: bool,
}

fn load_allowlist(path: Option<&Path>) -> Result<Vec<AllowEntry>, String> {
    let Some(path) = path else { return Ok(Vec::new()) };
    let text = read(path)?;
    let mut out = Vec::new();
    for line in text.lines().map(str::trim).filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let Some((p, n)) = line.split_once(" :: ") else {
            return Err(format!(
                "{}: malformed allowlist line `{line}` (want `path :: needle`)",
                path.display()
            ));
        };
        out.push(AllowEntry { path_suffix: p.trim().to_string(), needle: n.trim().to_string(), used: false });
    }
    Ok(out)
}

/// The mutex-poisoning idiom: `.unwrap()`/`.expect(` directly on
/// `.lock()`. Poisoning only happens after another thread already
/// panicked, so propagating it is the correct double-fault behavior and
/// allocates nothing on the success path.
fn lock_idiom(src: &Source, li: usize, code: &str, tok_at: usize) -> bool {
    let prefix = &code[..tok_at];
    if prefix.trim_end().ends_with(".lock()") {
        return true;
    }
    if prefix.trim().is_empty() {
        // The call starts the line (rustfmt chain style); look back to the
        // previous non-blank code line.
        let mut j = li;
        while j > 0 {
            j -= 1;
            let prev = src.lines[j].code.trim_end();
            if prev.trim().is_empty() {
                continue;
            }
            return prev.ends_with(".lock()");
        }
    }
    false
}

fn check_no_panic_source(src: &Source, allow: &mut [AllowEntry], out: &mut Vec<Violation>) {
    let skip = scan::test_mod_ranges(src);
    let path_str = src.path.to_string_lossy().replace('\\', "/");
    'line: for (li, line) in src.lines.iter().enumerate() {
        if skip.iter().any(|&(s, e)| li >= s && li <= e) {
            continue;
        }
        for tok in BANNED_PANIC {
            let mut from = 0;
            while let Some(pos) = line.code[from..].find(tok) {
                let at = from + pos;
                from = at + 1;
                if (*tok == ".unwrap()" || *tok == ".expect(") && lock_idiom(src, li, &line.code, at)
                {
                    continue;
                }
                let mut allowed = false;
                for entry in allow.iter_mut() {
                    if path_str.ends_with(&entry.path_suffix) && src.raw[li].contains(&entry.needle)
                    {
                        entry.used = true;
                        allowed = true;
                    }
                }
                if allowed {
                    continue 'line;
                }
                out.push(Violation {
                    path: src.path.clone(),
                    line: li + 1,
                    rule: "no-panic",
                    msg: format!("`{tok}` in non-test control-plane code"),
                });
            }
        }
    }
}

pub fn check_no_panic(dirs: &[PathBuf], allowlist: Option<&Path>) -> RuleResult {
    let mut allow = load_allowlist(allowlist)?;
    let mut out = Vec::new();
    for dir in dirs {
        for file in scan::rust_files(dir).map_err(|e| format!("{}: {e}", dir.display()))? {
            let src = scan_file(&file)?;
            check_no_panic_source(&src, &mut allow, &mut out);
        }
    }
    for entry in &allow {
        if !entry.used {
            if let Some(path) = allowlist {
                out.push(Violation {
                    path: path.to_path_buf(),
                    line: 0,
                    rule: "no-panic",
                    msg: format!(
                        "stale allowlist entry `{} :: {}` matched nothing",
                        entry.path_suffix, entry.needle
                    ),
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan_str;

    #[test]
    fn clone_marker_exempts_scalar_clones() {
        let text = "fn hot() {\n    let a = x.clone(); // lint: clone-ok — scalar enum\n    let b = y.clone();\n}\n";
        let src = scan_str(PathBuf::from("h.rs"), text);
        let (s, e) = scan::fn_def(&src, "hot").unwrap();
        let mut hits = 0;
        for li in s..=e {
            if src.lines[li].code.contains(".clone()")
                && !src.raw[li].contains(CLONE_OK)
                && !(li > 0 && src.raw[li - 1].contains(CLONE_OK))
            {
                hits += 1;
            }
        }
        assert_eq!(hits, 1, "only the unmarked clone is flagged");
    }

    #[test]
    fn banned_tokens_in_strings_do_not_fire() {
        let src = scan_str(PathBuf::from("s.rs"), "fn hot() { log(\"Vec::new()\"); }\n");
        let (s, e) = scan::fn_def(&src, "hot").unwrap();
        for li in s..=e {
            for tok in BANNED_ALLOC {
                assert!(!src.lines[li].code.contains(tok), "{tok} leaked into code channel");
            }
        }
    }

    #[test]
    fn lock_idiom_same_line_and_chain_style() {
        let text = "fn f() {\n    a.lock().unwrap().push(1);\n    b\n        .lock()\n        .unwrap()\n        .push(2);\n    c.unwrap();\n}\n";
        let src = scan_str(PathBuf::from("l.rs"), text);
        let mut out = Vec::new();
        check_no_panic_source(&src, &mut [], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 7);
    }

    #[test]
    fn test_modules_are_exempt() {
        let text = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); panic!(\"boom\"); }\n}\n";
        let src = scan_str(PathBuf::from("t.rs"), text);
        let mut out = Vec::new();
        check_no_panic_source(&src, &mut [], &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn unwrap_or_family_is_not_flagged() {
        let text = "fn f() { a.unwrap_or(0); b.unwrap_or_else(|| 1); c.unwrap_or_default(); }\n";
        let src = scan_str(PathBuf::from("u.rs"), text);
        let mut out = Vec::new();
        check_no_panic_source(&src, &mut [], &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn doc_table_parser_handles_decorated_cells() {
        let doc = "| op | fields |\n|----|--------|\n| `submit-graph` (cold) | `graph: map` |\n| `fetch-data` (w2w) | `run: uint` |\n\n| Path | Ops |\n|---|---|\n| hot | `not-an-op-table` |\n";
        let ops = doc_table_ops(doc);
        let names: Vec<&str> = ops.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(names, vec!["submit-graph", "fetch-data"]);
    }

    #[test]
    fn safety_comment_block_is_recognized() {
        let text = "// SAFETY: sole instance lives behind the global mutex;\n// no method leaks a reference past the guard.\nunsafe impl Send for H {}\n\nunsafe impl Sync for H {}\n";
        let src = scan_str(PathBuf::from("u.rs"), text);
        let mut out = Vec::new();
        check_safety_source(&src, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 5);
    }
}
