//! `cargo xtask lint` — repo-local invariant checks.
//!
//! Rules (details in `rules.rs` and docs/verification.md):
//!   1. hotpath        — no allocating calls in `xtask/hotpath.txt` functions
//!   2. protocol-ops   — op strings consistent across Msg::op(), the codec,
//!                       peek_op call sites, and docs/protocol.md
//!   3. safety-comment — every `unsafe` carries a `// SAFETY:` comment
//!   4. no-panic       — no unwrap/expect/panic! in non-test server/worker/
//!                       protocol code (mutex-poisoning idiom + reviewed
//!                       allowlist excepted)
//!
//! `cargo xtask lint --self-check` runs every rule against the seeded
//! violations in `xtask/fixtures/` and fails unless each rule reports each
//! planted defect: the checkers themselves are tested red, not just
//! observed green.

mod rules;
mod scan;

use rules::Violation;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // xtask lives at <repo>/xtask; CARGO_MANIFEST_DIR is compile-time, so
    // the tool works from any invocation directory.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent dir").to_path_buf()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let repo = repo_root();
            let code = if args.iter().any(|a| a == "--self-check") {
                self_check(&repo)
            } else {
                lint(&repo)
            };
            std::process::exit(code);
        }
        _ => {
            eprintln!("usage: cargo xtask lint [--self-check]");
            std::process::exit(2);
        }
    }
}

fn run_rule(name: &str, result: rules::RuleResult, all: &mut Vec<Violation>) -> bool {
    match result {
        Ok(v) => {
            println!("lint: {name}: {} finding(s)", v.len());
            all.extend(v);
            true
        }
        Err(e) => {
            eprintln!("lint: {name}: error: {e}");
            false
        }
    }
}

fn lint(repo: &Path) -> i32 {
    let rust = repo.join("rust/src");
    let mut all = Vec::new();
    let mut ok = true;
    ok &= run_rule("hotpath", rules::check_hotpath(repo, &repo.join("xtask/hotpath.txt")), &mut all);
    ok &= run_rule(
        "protocol-ops",
        rules::check_protocol_ops(
            &rust.join("protocol/messages.rs"),
            &rust.join("protocol/codec.rs"),
            &repo.join("docs/protocol.md"),
            &rust,
        ),
        &mut all,
    );
    ok &= run_rule("safety-comment", rules::check_safety(&rust), &mut all);
    ok &= run_rule(
        "no-panic",
        rules::check_no_panic(
            &[rust.join("server"), rust.join("worker"), rust.join("protocol")],
            Some(&repo.join("xtask/lint_allow.txt")),
        ),
        &mut all,
    );
    if !ok {
        return 2;
    }
    if all.is_empty() {
        println!("lint: clean");
        return 0;
    }
    for v in &all {
        println!("{v}");
    }
    println!("lint: {} violation(s)", all.len());
    1
}

/// Assert that `result` contains a violation whose message contains each
/// needle — i.e. the rule goes red on its seeded fixture.
fn expect_caught(name: &str, result: rules::RuleResult, needles: &[&str], failures: &mut u32) {
    match result {
        Err(e) => {
            eprintln!("self-check: {name}: rule errored instead of reporting: {e}");
            *failures += 1;
        }
        Ok(found) => {
            for needle in needles {
                if found.iter().any(|v| v.msg.contains(needle)) {
                    println!("self-check: {name}: caught seeded `{needle}`");
                } else {
                    eprintln!(
                        "self-check: {name}: MISSED seeded `{needle}`; rule reported: {:?}",
                        found.iter().map(|v| v.msg.as_str()).collect::<Vec<_>>()
                    );
                    *failures += 1;
                }
            }
        }
    }
}

fn self_check(repo: &Path) -> i32 {
    let fx = repo.join("xtask/fixtures");
    let mut failures = 0u32;

    expect_caught(
        "hotpath",
        rules::check_hotpath(repo, &fx.join("hotpath.txt")),
        &["`format!`", "`.to_owned()`", "`Box::new(`", "`.clone()`"],
        &mut failures,
    );
    expect_caught(
        "protocol-ops",
        rules::check_protocol_ops(
            &fx.join("proto_messages.rs"),
            &fx.join("proto_codec.rs"),
            &fx.join("proto_protocol.md"),
            &fx, // peek_op sweep over the fixtures themselves
        ),
        &[
            "op `ghost-op` never appears",
            "op `ghost-op` missing from the op tables",
            "documented op `phantom-op`",
            "peek_op compared against unknown op `typo-op`",
        ],
        &mut failures,
    );
    expect_caught(
        "safety-comment",
        rules::check_safety(&fx.join("unsafe_bad_dir")),
        &["`unsafe` without a `// SAFETY:` comment"],
        &mut failures,
    );
    expect_caught(
        "no-panic",
        rules::check_no_panic(&[fx.join("panic_bad_dir")], None),
        &["`.unwrap()`", "`panic!(`"],
        &mut failures,
    );

    // The fixtures also prove the rules are not over-broad: the documented
    // `unsafe` in the safety fixture, and the test module and lock-idiom
    // lines in the no-panic fixture, must NOT be flagged.
    match rules::check_safety(&fx.join("unsafe_bad_dir")) {
        Ok(found) if found.len() == 2 => {
            println!("self-check: safety-comment: documented site not flagged (2 findings, 2 expected)");
        }
        Ok(found) => {
            eprintln!("self-check: safety-comment: expected exactly 2 findings, got {}", found.len());
            failures += 1;
        }
        Err(e) => {
            eprintln!("self-check: safety-comment: {e}");
            failures += 1;
        }
    }
    match rules::check_no_panic(&[fx.join("panic_bad_dir")], None) {
        Ok(found) if found.len() == 2 => {
            println!("self-check: no-panic: exemptions held ({} findings, 2 expected)", found.len());
        }
        Ok(found) => {
            eprintln!(
                "self-check: no-panic: expected exactly 2 findings, got {}: {:?}",
                found.len(),
                found.iter().map(|v| format!("{v}")).collect::<Vec<_>>()
            );
            failures += 1;
        }
        Err(e) => {
            eprintln!("self-check: no-panic: {e}");
            failures += 1;
        }
    }

    if failures == 0 {
        println!("self-check: all rules fire on their seeded violations");
        0
    } else {
        eprintln!("self-check: {failures} expectation(s) failed");
        1
    }
}
