//! Lexical layer for the repo lint: splits Rust source into per-line
//! code / comment / string-literal channels, and provides brace-matched
//! region lookup (function bodies, `#[cfg(test)]` modules) on the code
//! channel.
//!
//! This is deliberately *not* a parser. Every check in [`crate::rules`] is
//! a token-presence invariant (no allocating call inside a registered hot
//! function, every `unsafe` carries a `SAFETY:` comment, ...), and a
//! hand-rolled scanner keeps the tool dependency-free — the build
//! environment cannot fetch `syn`. What the scanner does understand is
//! exactly the lexical structure that would otherwise produce false
//! positives: line comments, nested block comments, string / byte-string /
//! char literals with escapes, raw strings with `#` fences, and lifetimes
//! (`'a`) versus char literals (`'a'`).

use std::io;
use std::path::{Path, PathBuf};

/// One source line, split into channels.
#[derive(Debug, Default)]
pub struct Line {
    /// Code with comments removed and string-literal *contents* blanked
    /// to spaces (the delimiting quotes remain, so `"x".len()` still
    /// reads as a method call on a string).
    pub code: String,
    /// Comment text appearing on this line (line or block).
    pub comment: String,
    /// Contents of string literals that *end* on this line.
    pub strings: Vec<String>,
}

/// A scanned source file.
pub struct Source {
    pub path: PathBuf,
    pub raw: Vec<String>,
    pub lines: Vec<Line>,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    /// Inside a (possibly nested) block comment.
    Block(usize),
    /// Inside a string literal; `Some(n)` = raw string closed by `"` + n `#`s.
    Str(Option<usize>),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scan source text already in memory (tests, fixtures).
pub fn scan_str(path: PathBuf, text: &str) -> Source {
    let raw: Vec<String> = text.split('\n').map(str::to_string).collect();
    let mut lines = Vec::with_capacity(raw.len());
    let mut state = State::Code;
    let mut cur_string = String::new();

    for rawline in &raw {
        let chars: Vec<char> = rawline.chars().collect();
        let mut line = Line::default();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match state {
                State::Block(depth) => {
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(depth + 1);
                        line.comment.push_str("/*");
                        i += 2;
                    } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                        state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                        line.comment.push_str("*/");
                        i += 2;
                    } else {
                        line.comment.push(c);
                        i += 1;
                    }
                }
                State::Str(raw_hashes) => match raw_hashes {
                    None => {
                        if c == '\\' && i + 1 < chars.len() {
                            cur_string.push(chars[i + 1]);
                            line.code.push_str("  ");
                            i += 2;
                        } else if c == '"' {
                            line.code.push('"');
                            line.strings.push(std::mem::take(&mut cur_string));
                            state = State::Code;
                            i += 1;
                        } else {
                            cur_string.push(c);
                            line.code.push(' ');
                            i += 1;
                        }
                    }
                    Some(n) => {
                        let closes = c == '"'
                            && i + n < chars.len()
                            && chars[i + 1..i + 1 + n].iter().all(|&h| h == '#');
                        if closes {
                            line.code.push('"');
                            for _ in 0..n {
                                line.code.push('#');
                            }
                            line.strings.push(std::mem::take(&mut cur_string));
                            state = State::Code;
                            i += 1 + n;
                        } else {
                            cur_string.push(c);
                            line.code.push(' ');
                            i += 1;
                        }
                    }
                },
                State::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        let rest: String = chars[i..].iter().collect();
                        line.comment.push_str(&rest);
                        break;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(1);
                        line.comment.push_str("/*");
                        i += 2;
                    } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident(chars[i - 1])) {
                        // Raw-string prefix? (`r"`, `r#"`, `br"`, ...)
                        let mut j = i;
                        if chars[j] == 'b' {
                            j += 1;
                        }
                        if chars.get(j) == Some(&'r') {
                            j += 1;
                            let mut n = 0;
                            while chars.get(j) == Some(&'#') {
                                n += 1;
                                j += 1;
                            }
                            if chars.get(j) == Some(&'"') {
                                for &p in &chars[i..=j] {
                                    line.code.push(p);
                                }
                                cur_string.clear();
                                state = State::Str(Some(n));
                                i = j + 1;
                                continue;
                            }
                        }
                        line.code.push(c);
                        i += 1;
                    } else if c == '"' {
                        line.code.push('"');
                        cur_string.clear();
                        state = State::Str(None);
                        i += 1;
                    } else if c == '\'' {
                        // Char literal vs lifetime.
                        if chars.get(i + 1) == Some(&'\\') {
                            // Escaped char literal: find the closing quote.
                            let mut j = i + 3;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            line.code.push_str("''");
                            i = (j + 1).min(chars.len());
                        } else if chars.get(i + 2) == Some(&'\'') {
                            line.code.push_str("' '");
                            i += 3;
                        } else {
                            // Lifetime (`'a`): keep the tick, continue.
                            line.code.push('\'');
                            i += 1;
                        }
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                }
            }
        }
        if let State::Str(_) = state {
            cur_string.push('\n');
        }
        lines.push(line);
    }
    Source { path, raw, lines }
}

/// Scan a file from disk.
pub fn scan(path: &Path) -> io::Result<Source> {
    let text = std::fs::read_to_string(path)?;
    Ok(scan_str(path.to_path_buf(), &text))
}

/// First whole-word occurrence of `word` in `chars` at or after `from`
/// (char index).
fn find_word_in(chars: &[char], word: &str, from: usize) -> Option<usize> {
    let w: Vec<char> = word.chars().collect();
    if w.is_empty() || chars.len() < w.len() {
        return None;
    }
    for at in from..=chars.len() - w.len() {
        if chars[at..at + w.len()] == w[..]
            && (at == 0 || !is_ident(chars[at - 1]))
            && (at + w.len() == chars.len() || !is_ident(chars[at + w.len()]))
        {
            return Some(at);
        }
    }
    None
}

/// Whole-word search on one code line; returns a char index.
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let chars: Vec<char> = code.chars().collect();
    find_word_in(&chars, word, 0)
}

/// From `(from_line, from_col)` (char col), find the first `{` in code and
/// return the line index of its matching `}`.
pub fn match_brace(src: &Source, from_line: usize, from_col: usize) -> Option<usize> {
    let mut depth: i64 = 0;
    let mut started = false;
    for (li, line) in src.lines.iter().enumerate().skip(from_line) {
        let start = if li == from_line { from_col } else { 0 };
        for (ci, c) in line.code.chars().enumerate() {
            if ci < start {
                continue;
            }
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => {
                    depth -= 1;
                    if started && depth == 0 {
                        return Some(li);
                    }
                }
                _ => {}
            }
        }
    }
    None
}

/// Locate `fn <name>` and return the inclusive line range of the item
/// (definition line through the body's closing brace). Call sites are
/// rejected: the token before `name` must be `fn` and the token after it
/// must open a parameter or generics list.
pub fn fn_def(src: &Source, name: &str) -> Option<(usize, usize)> {
    for (li, line) in src.lines.iter().enumerate() {
        let chars: Vec<char> = line.code.chars().collect();
        let mut from = 0;
        while let Some(at) = find_word_in(&chars, name, from) {
            from = at + 1;
            let before: String = chars[..at].iter().collect();
            let bt = before.trim_end();
            if !bt.ends_with("fn") {
                continue;
            }
            let bchars: Vec<char> = bt.chars().collect();
            if bchars.len() > 2 && is_ident(bchars[bchars.len() - 3]) {
                continue; // e.g. `xfn name`
            }
            let mut k = at + name.chars().count();
            while k < chars.len() && chars[k].is_whitespace() {
                k += 1;
            }
            if k < chars.len() && (chars[k] == '(' || chars[k] == '<') {
                let end = match_brace(src, li, at)?;
                return Some((li, end));
            }
        }
    }
    None
}

/// Inclusive line ranges of items annotated `#[cfg(test)]` (in this repo:
/// the per-file `mod tests` blocks).
pub fn test_mod_ranges(src: &Source) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut li = 0;
    while li < src.lines.len() {
        if src.lines[li].code.contains("#[cfg(test)]") {
            if let Some(end) = match_brace(src, li, 0) {
                out.push((li, end));
                li = end + 1;
                continue;
            }
        }
        li += 1;
    }
    out
}

/// Every `.rs` file under `root`, recursively, sorted for determinism.
pub fn rust_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(text: &str) -> Source {
        scan_str(PathBuf::from("test.rs"), text)
    }

    #[test]
    fn strings_are_blanked_and_captured() {
        let s = src(r#"let x = "Vec::new()"; x.len();"#);
        assert!(!s.lines[0].code.contains("Vec::new"));
        assert!(s.lines[0].code.contains("x.len()"));
        assert_eq!(s.lines[0].strings, vec!["Vec::new()".to_string()]);
    }

    #[test]
    fn escapes_do_not_end_strings() {
        let s = src(r#"let x = "a\"b; Vec::new()"; done();"#);
        assert!(!s.lines[0].code.contains("Vec::new"));
        assert!(s.lines[0].code.contains("done()"));
        assert_eq!(s.lines[0].strings, vec![r#"a"b; Vec::new()"#.to_string()]);
    }

    #[test]
    fn comments_are_split_out() {
        let s = src("foo(); // Vec::new() in a comment\nbar();");
        assert!(!s.lines[0].code.contains("Vec::new"));
        assert!(s.lines[0].comment.contains("Vec::new"));
        assert!(s.lines[1].code.contains("bar()"));
    }

    #[test]
    fn nested_block_comments() {
        let s = src("a(); /* outer /* inner */ still */ b();");
        assert!(s.lines[0].code.contains("a()"));
        assert!(s.lines[0].code.contains("b()"));
        assert!(!s.lines[0].code.contains("inner"));
        assert!(!s.lines[0].code.contains("still"));
    }

    #[test]
    fn raw_strings_with_fences() {
        let s = src(r##"let x = r#"Vec::new() "quoted" inside"#; tail();"##);
        assert!(!s.lines[0].code.contains("Vec::new"));
        assert!(s.lines[0].code.contains("tail()"));
        assert_eq!(s.lines[0].strings.len(), 1);
        assert!(s.lines[0].strings[0].contains("quoted"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        // The '"' char literal must not open a string; 'a must stay a
        // lifetime so the rest of the line is still code.
        let s = src("fn f<'a>(x: &'a str) -> char { let q = '\"'; q }");
        assert!(s.lines[0].code.contains("let q ="));
        assert!(s.lines[0].code.contains("&'a str"));
        assert!(s.lines[0].strings.is_empty());
    }

    #[test]
    fn multiline_strings_span_lines() {
        let s = src("let x = \"first\nVec::new()\nlast\"; end();");
        assert!(!s.lines[1].code.contains("Vec::new"));
        assert!(s.lines[2].code.contains("end()"));
        assert_eq!(s.lines[2].strings, vec!["first\nVec::new()\nlast".to_string()]);
    }

    #[test]
    fn fn_def_skips_call_sites() {
        let text = "fn caller() {\n    target();\n}\nfn target() {\n    body();\n}\n";
        let s = src(text);
        let (start, end) = fn_def(&s, "target").unwrap();
        assert_eq!((start, end), (3, 5));
    }

    #[test]
    fn fn_def_ignores_comment_mentions() {
        let text = "// fn ghost() is documented here\nfn ghost() { real(); }\n";
        let s = src(text);
        assert_eq!(fn_def(&s, "ghost").unwrap().0, 1);
    }

    #[test]
    fn test_mod_range_is_brace_matched() {
        let text = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let s = src(text);
        assert_eq!(test_mod_ranges(&s), vec![(1, 4)]);
    }
}
