//! Fig 9 (extension) — per-client AOT degradation under concurrent
//! multi-graph load, in the simulator AND over real TCP.
//!
//! The paper benchmarks one graph at a time; the first section measures
//! what happens when 1, 4 and 16 clients submit interleaved graphs to one
//! shared simulated server: the reactor serializes message handling, so
//! per-run AOT (run makespan / run tasks) grows with client count — much
//! faster for the emulated CPython server than for the Rust one.
//!
//! The second section closes the ROADMAP "sim/runtime parity" item: the
//! same workload runs against a *real* TCP server with N client threads
//! and zero workers (§IV-D — no execution or data plane, so both sides
//! measure pure server overhead), and the per-client AOT *degradation
//! curves* (mean AOT at N clients ÷ mean AOT at 1 client) are asserted to
//! agree within a coarse tolerance. Absolute AOTs differ — the simulator
//! charges a calibrated cost model, the TCP server pays real syscalls —
//! but the dimensionless degradation shape is what Fig 9 claims, and a
//! gross divergence here means the simulator no longer models the server.

use rsds::client::Client;
use rsds::graphgen::{concurrent, CONCURRENT_MIX_DEFAULT};
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::sim::{simulate_concurrent, SimConfig};
use rsds::worker::zero::run_zero_worker;
use rsds::worker::WorkerConfig;

/// Sim-vs-TCP degradation curves may differ by at most this factor per
/// point (log-symmetric). Coarse by design: real sockets and thread
/// scheduling are noisy; the assertion catches model breakage, not jitter.
const PARITY_TOL: f64 = 3.0;

fn sim_mean_aot(n_clients: usize, mix: &[&str], n_workers: usize) -> f64 {
    let graphs = concurrent(n_clients, mix);
    let cfg = SimConfig {
        n_workers,
        profile: RuntimeProfile::rust(),
        scheduler: "ws".into(),
        zero_worker: true,
        ..SimConfig::default()
    };
    let r = simulate_concurrent(&graphs, &cfg);
    assert!(!r.timed_out, "sim timed out at {n_clients} clients");
    r.runs.iter().map(|x| x.aot_us).sum::<f64>() / r.runs.len() as f64
}

/// Real server + zero workers + `n_clients` client threads; returns the
/// mean server-measured AOT across the runs.
fn tcp_mean_aot(n_clients: usize, mix: &[&str], n_workers: usize) -> f64 {
    let srv = serve(ServerConfig::default()).expect("server start");
    let addr = srv.addr.to_string();
    let zws: Vec<_> = (0..n_workers)
        .map(|i| {
            run_zero_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("z{i}"),
                ncores: 1,
                node: 0,
            })
            .expect("zero worker start")
        })
        .collect();
    let graphs = concurrent(n_clients, mix);
    let handles: Vec<_> = graphs
        .into_iter()
        .enumerate()
        .map(|(i, g)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("fig9-{i}")).expect("connect");
                let res = c.run_graph(&g).expect("run");
                res.makespan_us as f64 / res.n_tasks as f64
            })
        })
        .collect();
    let aots: Vec<f64> = handles.into_iter().map(|h| h.join().expect("client thread")).collect();
    for z in &zws {
        z.shutdown();
    }
    srv.shutdown();
    aots.iter().sum::<f64>() / aots.len() as f64
}

fn sim_tables(quick: bool) {
    let combos: [(&str, RuntimeProfile, &str); 4] = [
        ("dask/ws", RuntimeProfile::python(), "dask-ws"),
        ("dask/random", RuntimeProfile::python(), "random"),
        ("rsds/ws", RuntimeProfile::rust(), "ws"),
        ("rsds/random", RuntimeProfile::rust(), "random"),
    ];
    let node_counts: &[usize] = if quick { &[1] } else { &[1, 7] };
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    for &nodes in node_counts {
        println!(
            "\n== Fig 9: per-client AOT (µs/task) vs concurrent clients, {} workers ==",
            nodes * 24
        );
        print!("{:<14}", "clients");
        for (label, _, _) in &combos {
            print!(" {:>14}", label);
        }
        println!("   (mix: {})", CONCURRENT_MIX_DEFAULT.join(", "));
        let mut baselines = [0.0f64; 4];
        for &n_clients in client_counts {
            let graphs = concurrent(n_clients, CONCURRENT_MIX_DEFAULT);
            print!("{:<14}", n_clients);
            for (i, (label, profile, sched)) in combos.iter().enumerate() {
                let cfg = SimConfig::nodes(nodes, profile.clone(), sched);
                let r = simulate_concurrent(&graphs, &cfg);
                assert!(!r.timed_out, "{label} timed out at {n_clients} clients");
                assert_eq!(r.in_flight_steals_at_end, 0, "{label}: leaked steals");
                let mean_aot: f64 =
                    r.runs.iter().map(|x| x.aot_us).sum::<f64>() / r.runs.len() as f64;
                if n_clients == 1 {
                    baselines[i] = mean_aot;
                    print!(" {:>14.1}", mean_aot);
                } else {
                    print!(" {:>8.1} ({:.1}×)", mean_aot, mean_aot / baselines[i]);
                }
            }
            println!();
        }
    }
}

fn parity_section(quick: bool) {
    let mix: &[&str] = if quick { &["merge-500", "tree-6"] } else { &["merge-2000", "tree-9"] };
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let n_workers = 8;
    println!(
        "\n== Fig 9 parity: TCP (zero workers) vs sim degradation curves \
         ({n_workers} workers, mix: {}) ==",
        mix.join(", ")
    );
    println!(
        "{:<10} {:>14} {:>14} {:>12} {:>12} {:>10}",
        "clients", "sim AOT µs", "tcp AOT µs", "sim deg", "tcp deg", "ratio"
    );
    let sim: Vec<f64> =
        client_counts.iter().map(|&n| sim_mean_aot(n, mix, n_workers)).collect();
    // Two TCP reps per point, keep the min: real-socket timing is noisy and
    // the curve shape is what parity is about.
    let tcp: Vec<f64> = client_counts
        .iter()
        .map(|&n| {
            let a = tcp_mean_aot(n, mix, n_workers);
            let b = tcp_mean_aot(n, mix, n_workers);
            a.min(b)
        })
        .collect();
    for (i, &n) in client_counts.iter().enumerate() {
        let sim_deg = sim[i] / sim[0];
        let tcp_deg = tcp[i] / tcp[0];
        let ratio = sim_deg / tcp_deg;
        println!(
            "{:<10} {:>14.1} {:>14.1} {:>11.2}x {:>11.2}x {:>10.2}",
            n, sim[i], tcp[i], sim_deg, tcp_deg, ratio
        );
        assert!(
            (ratio.ln()).abs() <= PARITY_TOL.ln(),
            "sim/runtime parity broken at {n} clients: sim degrades {sim_deg:.2}x, \
             tcp degrades {tcp_deg:.2}x (tolerance {PARITY_TOL}x)"
        );
    }
    println!("parity OK: degradation curves agree within {PARITY_TOL}x at every point");
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    sim_tables(quick);
    parity_section(quick);
    println!(
        "\nper-run AOT = run makespan / run tasks, averaged over clients; \
         ×: degradation vs a single client on the same server"
    );
}
