//! Fig 9 (extension) — per-client AOT degradation under concurrent
//! multi-graph load, in the simulator AND over real TCP.
//!
//! The paper benchmarks one graph at a time; the first section measures
//! what happens when 1, 4 and 16 clients submit interleaved graphs to one
//! shared simulated server: the reactor serializes message handling, so
//! per-run AOT (run makespan / run tasks) grows with client count — much
//! faster for the emulated CPython server than for the Rust one.
//!
//! The second section closes the ROADMAP "sim/runtime parity" item: the
//! same workload runs against a *real* TCP server with N client threads
//! and zero workers (§IV-D — no execution or data plane, so both sides
//! measure pure server overhead), and the per-client AOT *degradation
//! curves* (mean AOT at N clients ÷ mean AOT at 1 client) are asserted to
//! agree within a coarse tolerance. Absolute AOTs differ — the simulator
//! charges a calibrated cost model, the TCP server pays real syscalls —
//! but the dimensionless degradation shape is what Fig 9 claims, and a
//! gross divergence here means the simulator no longer models the server.
//!
//! The third section exercises the sharded control plane at fleet scale:
//! 256 (quick) / 1024 (full) concurrent TCP clients against a 1-shard and
//! a 4-shard server. It demonstrates the thread model is `O(shards +
//! workers)` — not `O(clients)` as the old thread-per-connection design
//! was — records per-shard throughput to `BENCH_pr7.json`, and (given
//! ≥ 4 cores) asserts the 4-shard server outscales the 1-shard one.
//!
//! `RSDS_BENCH_SECTION=sim|parity|shards` runs a subset of the sections
//! (comma-separated; empty or unset runs all three).

use rsds::client::Client;
use rsds::graphgen::{concurrent, CONCURRENT_MIX_DEFAULT};
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::sim::{simulate_concurrent, SimConfig};
use rsds::worker::zero::run_zero_worker;
use rsds::worker::WorkerConfig;

/// Sim-vs-TCP degradation curves may differ by at most this factor per
/// point (log-symmetric). Coarse by design: real sockets and thread
/// scheduling are noisy; the assertion catches model breakage, not jitter.
const PARITY_TOL: f64 = 3.0;

fn sim_mean_aot(n_clients: usize, mix: &[&str], n_workers: usize) -> f64 {
    let graphs = concurrent(n_clients, mix);
    let cfg = SimConfig {
        n_workers,
        profile: RuntimeProfile::rust(),
        scheduler: "ws".into(),
        zero_worker: true,
        ..SimConfig::default()
    };
    let r = simulate_concurrent(&graphs, &cfg);
    assert!(!r.timed_out, "sim timed out at {n_clients} clients");
    r.runs.iter().map(|x| x.aot_us).sum::<f64>() / r.runs.len() as f64
}

/// Real server + zero workers + `n_clients` client threads; returns the
/// mean server-measured AOT across the runs.
fn tcp_mean_aot(n_clients: usize, mix: &[&str], n_workers: usize) -> f64 {
    // Pinned to one shard: the simulator models a single serializing
    // reactor, and parity is a statement about that model. Multi-shard
    // behavior is measured by `shard_scaling_section` instead.
    let srv = serve(ServerConfig { shards: 1, ..ServerConfig::default() }).expect("server start");
    let addr = srv.addr.to_string();
    let zws: Vec<_> = (0..n_workers)
        .map(|i| {
            run_zero_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("z{i}"),
                ncores: 1,
                node: 0,
                memory_limit: None,
                data_plane: Default::default(),
            })
            .expect("zero worker start")
        })
        .collect();
    let graphs = concurrent(n_clients, mix);
    let handles: Vec<_> = graphs
        .into_iter()
        .enumerate()
        .map(|(i, g)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("fig9-{i}")).expect("connect");
                let res = c.run_graph(&g).expect("run");
                res.makespan_us as f64 / res.n_tasks as f64
            })
        })
        .collect();
    let aots: Vec<f64> = handles.into_iter().map(|h| h.join().expect("client thread")).collect();
    for z in &zws {
        z.shutdown();
    }
    srv.shutdown();
    aots.iter().sum::<f64>() / aots.len() as f64
}

fn sim_tables(quick: bool) {
    let combos: [(&str, RuntimeProfile, &str); 4] = [
        ("dask/ws", RuntimeProfile::python(), "dask-ws"),
        ("dask/random", RuntimeProfile::python(), "random"),
        ("rsds/ws", RuntimeProfile::rust(), "ws"),
        ("rsds/random", RuntimeProfile::rust(), "random"),
    ];
    let node_counts: &[usize] = if quick { &[1] } else { &[1, 7] };
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    for &nodes in node_counts {
        println!(
            "\n== Fig 9: per-client AOT (µs/task) vs concurrent clients, {} workers ==",
            nodes * 24
        );
        print!("{:<14}", "clients");
        for (label, _, _) in &combos {
            print!(" {:>14}", label);
        }
        println!("   (mix: {})", CONCURRENT_MIX_DEFAULT.join(", "));
        let mut baselines = [0.0f64; 4];
        for &n_clients in client_counts {
            let graphs = concurrent(n_clients, CONCURRENT_MIX_DEFAULT);
            print!("{:<14}", n_clients);
            for (i, (label, profile, sched)) in combos.iter().enumerate() {
                let cfg = SimConfig::nodes(nodes, profile.clone(), sched);
                let r = simulate_concurrent(&graphs, &cfg);
                assert!(!r.timed_out, "{label} timed out at {n_clients} clients");
                assert_eq!(r.in_flight_steals_at_end, 0, "{label}: leaked steals");
                let mean_aot: f64 =
                    r.runs.iter().map(|x| x.aot_us).sum::<f64>() / r.runs.len() as f64;
                if n_clients == 1 {
                    baselines[i] = mean_aot;
                    print!(" {:>14.1}", mean_aot);
                } else {
                    print!(" {:>8.1} ({:.1}×)", mean_aot, mean_aot / baselines[i]);
                }
            }
            println!();
        }
    }
}

fn parity_section(quick: bool) {
    let mix: &[&str] = if quick { &["merge-500", "tree-6"] } else { &["merge-2000", "tree-9"] };
    let client_counts: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let n_workers = 8;
    println!(
        "\n== Fig 9 parity: TCP (zero workers) vs sim degradation curves \
         ({n_workers} workers, mix: {}) ==",
        mix.join(", ")
    );
    println!(
        "{:<10} {:>14} {:>14} {:>12} {:>12} {:>10}",
        "clients", "sim AOT µs", "tcp AOT µs", "sim deg", "tcp deg", "ratio"
    );
    let sim: Vec<f64> =
        client_counts.iter().map(|&n| sim_mean_aot(n, mix, n_workers)).collect();
    // Two TCP reps per point, keep the min: real-socket timing is noisy and
    // the curve shape is what parity is about.
    let tcp: Vec<f64> = client_counts
        .iter()
        .map(|&n| {
            let a = tcp_mean_aot(n, mix, n_workers);
            let b = tcp_mean_aot(n, mix, n_workers);
            a.min(b)
        })
        .collect();
    for (i, &n) in client_counts.iter().enumerate() {
        let sim_deg = sim[i] / sim[0];
        let tcp_deg = tcp[i] / tcp[0];
        let ratio = sim_deg / tcp_deg;
        println!(
            "{:<10} {:>14.1} {:>14.1} {:>11.2}x {:>11.2}x {:>10.2}",
            n, sim[i], tcp[i], sim_deg, tcp_deg, ratio
        );
        assert!(
            (ratio.ln()).abs() <= PARITY_TOL.ln(),
            "sim/runtime parity broken at {n} clients: sim degrades {sim_deg:.2}x, \
             tcp degrades {tcp_deg:.2}x (tolerance {PARITY_TOL}x)"
        );
    }
    println!("parity OK: degradation curves agree within {PARITY_TOL}x at every point");
}

/// One shard-scaling measurement: `clients` concurrent TCP clients, each
/// submitting one small graph, against a `shards`-shard server.
struct ShardRow {
    shards: usize,
    clients: usize,
    tasks_total: u64,
    wall_s: f64,
    tasks_per_s: f64,
    /// Process-wide thread count sampled mid-flight (0 if unreadable).
    peak_threads: usize,
}

/// Linux thread count of this process (clients + server + workers all
/// live here, so the `O(shards)` claim is checked against `clients + ε`).
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn shard_throughput(shards: usize, n_clients: usize, spec: &str, n_workers: usize) -> ShardRow {
    let srv =
        serve(ServerConfig { shards, ..ServerConfig::default() }).expect("server start");
    let addr = srv.addr.to_string();
    let zws: Vec<_> = (0..n_workers)
        .map(|i| {
            run_zero_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("zs{i}"),
                ncores: 1,
                node: 0,
                memory_limit: None,
                data_plane: Default::default(),
            })
            .expect("zero worker start")
        })
        .collect();
    let graphs = concurrent(n_clients, &[spec]);
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = graphs
        .into_iter()
        .enumerate()
        .map(|(i, g)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr, &format!("fig9s-{i}")).expect("connect");
                let res = c.run_graph(&g).expect("run");
                res.n_tasks
            })
        })
        .collect();
    // Sample the process thread count while the fleet is in flight.
    std::thread::sleep(std::time::Duration::from_millis(100));
    let peak_threads = os_thread_count().unwrap_or(0);
    let tasks_total: u64 =
        handles.into_iter().map(|h| h.join().expect("client thread")).sum();
    let wall_s = t0.elapsed().as_secs_f64();
    for z in &zws {
        z.shutdown();
    }
    srv.shutdown();
    ShardRow {
        shards,
        clients: n_clients,
        tasks_total,
        wall_s,
        tasks_per_s: tasks_total as f64 / wall_s,
        peak_threads,
    }
}

fn write_shard_json(rows: &[ShardRow], scaling: f64, asserted: bool, quick: bool, cores: usize) {
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 7,\n");
    json.push_str("  \"bench\": \"fig9_shard_scaling\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"cores\": {cores},\n"));
    json.push_str(&format!("  \"scaling_4_shards_over_1\": {scaling:.3},\n"));
    json.push_str(&format!("  \"scaling_asserted\": {asserted},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"shards\": {}, \"clients\": {}, \"tasks_total\": {}, \
             \"wall_s\": {:.3}, \"tasks_per_s\": {:.1}, \
             \"tasks_per_s_per_shard\": {:.1}, \"peak_threads\": {}}}{}\n",
            r.shards,
            r.clients,
            r.tasks_total,
            r.wall_s,
            r.tasks_per_s,
            r.tasks_per_s / r.shards as f64,
            r.peak_threads,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr7.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr7.json"),
        Err(e) => eprintln!("could not write BENCH_pr7.json: {e}"),
    }
}

fn shard_scaling_section(quick: bool) {
    let (n_clients, spec) = if quick { (256, "merge-50") } else { (1024, "merge-100") };
    let n_workers = 4;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "\n== Fig 9 shard scaling: {n_clients} concurrent TCP clients ({spec} each), \
         {n_workers} zero workers, {cores} cores =="
    );
    println!(
        "{:<8} {:>10} {:>10} {:>14} {:>18} {:>14}",
        "shards", "tasks", "wall s", "tasks/s", "tasks/s/shard", "threads"
    );
    let rows: Vec<ShardRow> = [1usize, 4]
        .iter()
        .map(|&s| {
            let r = shard_throughput(s, n_clients, spec, n_workers);
            println!(
                "{:<8} {:>10} {:>10.2} {:>14.1} {:>18.1} {:>14}",
                r.shards,
                r.tasks_total,
                r.wall_s,
                r.tasks_per_s,
                r.tasks_per_s / r.shards as f64,
                r.peak_threads
            );
            r
        })
        .collect();
    // Thread model: everything (clients, server, workers) lives in this
    // process, so `clients + small constant` bounds the server's own
    // threads at O(shards + workers). The old design added ~2 threads per
    // connection and would blow straight through this.
    for r in &rows {
        if r.peak_threads > 0 {
            let bound = r.clients + 8 * n_workers + 64;
            assert!(
                r.peak_threads <= bound,
                "{} shards: {} threads for {} clients — server threads scale with \
                 clients (bound {bound})",
                r.shards,
                r.peak_threads,
                r.clients
            );
        }
    }
    let scaling = rows[1].tasks_per_s / rows[0].tasks_per_s;
    println!("4-shard vs 1-shard throughput: {scaling:.2}x");
    // The scaling assertion needs real parallelism; on a starved runner the
    // numbers are still recorded, just not gated.
    let min_scaling = if quick { 1.15 } else { 2.5 };
    let asserted = cores >= 4;
    if asserted {
        assert!(
            scaling >= min_scaling,
            "sharding does not scale: 4 shards gave {scaling:.2}x over 1 shard \
             (need >= {min_scaling}x with {cores} cores)"
        );
    } else {
        println!("({cores} cores < 4: scaling recorded, assertion skipped)");
    }
    write_shard_json(&rows, scaling, asserted, quick, cores);
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let section = std::env::var("RSDS_BENCH_SECTION").unwrap_or_default();
    let wants = |name: &str| section.is_empty() || section.split(',').any(|s| s.trim() == name);
    if wants("sim") {
        sim_tables(quick);
    }
    if wants("parity") {
        parity_section(quick);
    }
    if wants("shards") {
        shard_scaling_section(quick);
    }
    println!(
        "\nper-run AOT = run makespan / run tasks, averaged over clients; \
         ×: degradation vs a single client on the same server"
    );
}
