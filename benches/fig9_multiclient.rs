//! Fig 9 (extension) — per-client AOT degradation under concurrent
//! multi-graph load.
//!
//! The paper benchmarks one graph at a time; this measures what happens
//! when 1, 4 and 16 clients submit interleaved graphs to one shared
//! server: the reactor serializes message handling, so per-run AOT
//! (run makespan / run tasks) grows with client count — much faster for
//! the emulated CPython server than for the Rust one.

use rsds::graphgen::{concurrent, CONCURRENT_MIX_DEFAULT};
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate_concurrent, SimConfig};

fn main() {
    let combos: [(&str, RuntimeProfile, &str); 4] = [
        ("dask/ws", RuntimeProfile::python(), "dask-ws"),
        ("dask/random", RuntimeProfile::python(), "random"),
        ("rsds/ws", RuntimeProfile::rust(), "ws"),
        ("rsds/random", RuntimeProfile::rust(), "random"),
    ];
    for nodes in [1usize, 7] {
        println!(
            "\n== Fig 9: per-client AOT (µs/task) vs concurrent clients, {} workers ==",
            nodes * 24
        );
        print!("{:<14}", "clients");
        for (label, _, _) in &combos {
            print!(" {:>14}", label);
        }
        println!("   (mix: {})", CONCURRENT_MIX_DEFAULT.join(", "));
        let mut baselines = [0.0f64; 4];
        for n_clients in [1usize, 4, 16] {
            let graphs = concurrent(n_clients, CONCURRENT_MIX_DEFAULT);
            print!("{:<14}", n_clients);
            for (i, (label, profile, sched)) in combos.iter().enumerate() {
                let cfg = SimConfig::nodes(nodes, profile.clone(), sched);
                let r = simulate_concurrent(&graphs, &cfg);
                assert!(!r.timed_out, "{label} timed out at {n_clients} clients");
                assert_eq!(r.in_flight_steals_at_end, 0, "{label}: leaked steals");
                let mean_aot: f64 =
                    r.runs.iter().map(|x| x.aot_us).sum::<f64>() / r.runs.len() as f64;
                if n_clients == 1 {
                    baselines[i] = mean_aot;
                    print!(" {:>14.1}", mean_aot);
                } else {
                    print!(" {:>8.1} ({:.1}×)", mean_aot, mean_aot / baselines[i]);
                }
            }
            println!();
        }
    }
    println!(
        "\nper-run AOT = run makespan / run tasks, averaged over clients; \
         ×: degradation vs a single client on the same server"
    );
}
