//! Fig 3 — speedup of RSDS/ws over Dask/ws on the full suite at 1 and 7
//! nodes. Paper shape: RSDS wins nearly everywhere; advantage grows with
//! cluster size (Table II geomeans 1.28× → 1.66×).

use rsds::bench::paper::{print_speedups, reps_from_env, speedups, Combo};
use rsds::graphgen::paper_suite;

fn main() {
    let suite = paper_suite();
    let reps = reps_from_env(3);
    for nodes in [1usize, 7] {
        let series = speedups(&suite, Combo::DASK_WS, Combo::RSDS_WS, nodes, reps, false);
        print_speedups(
            &format!("Fig 3: rsds/ws vs dask/ws, {nodes} node(s) = {} workers", nodes * 24),
            &series,
        );
        let paper = if nodes == 1 { 1.28 } else { 1.66 };
        println!("  paper geomean at this size: {paper}×");
    }
}
