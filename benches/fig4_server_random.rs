//! Fig 4 — speedup of RSDS/random over Dask/ws: the paper's evidence that
//! the RSDS gain comes from the runtime, not from better schedules
//! (geomeans 1.04× at 24 workers, 1.41× at 168).

use rsds::bench::paper::{print_speedups, reps_from_env, speedups, Combo};
use rsds::graphgen::paper_suite;

fn main() {
    let suite = paper_suite();
    let reps = reps_from_env(3);
    for nodes in [1usize, 7] {
        let series = speedups(&suite, Combo::DASK_WS, Combo::RSDS_RANDOM, nodes, reps, false);
        print_speedups(
            &format!("Fig 4: rsds/random vs dask/ws, {nodes} node(s) = {} workers", nodes * 24),
            &series,
        );
        let paper = if nodes == 1 { 1.04 } else { 1.41 };
        println!("  paper geomean at this size: {paper}×");
    }
}
