//! Fig 8 — AOT on the merge benchmark under the zero worker:
//! (top) scaling the task count at fixed cluster, (bottom) scaling the
//! worker count at fixed task count.
//!
//! Paper shapes: AOT grows with task count regardless of scheduler
//! (runtime bookkeeping), while with added workers the work-stealing AOT
//! grows and the random AOT stays nearly constant; RSDS stays well under
//! Dask everywhere, its ws overhead flat to ~100 workers then rising.

use rsds::bench::paper::{reps_from_env, Combo};
use rsds::graphgen::merge;
use rsds::sim::{simulate, SimConfig};

fn aot(n_tasks: u32, workers: usize, combo: Combo, reps: usize) -> f64 {
    let graph = merge(n_tasks);
    let mut total = 0.0;
    for rep in 0..reps {
        let cfg = SimConfig {
            n_workers: workers,
            zero_worker: true,
            seed: 2020 + rep as u64,
            ..SimConfig::nodes(1, combo.profile(), combo.sched_impl())
        };
        total += simulate(&graph, &cfg).makespan_us;
    }
    total / reps as f64 / (n_tasks as f64 + 1.0)
}

fn main() {
    let reps = reps_from_env(3);
    let combos = [Combo::DASK_WS, Combo::DASK_RANDOM, Combo::RSDS_WS, Combo::RSDS_RANDOM];

    println!("== Fig 8 (top): AOT (µs/task) vs task count, 24 workers ==");
    print!("{:>9}", "tasks");
    for c in &combos {
        print!(" {:>14}", c.label());
    }
    println!();
    for n in [10_000u32, 25_000, 50_000, 100_000] {
        print!("{n:>9}");
        for c in &combos {
            print!(" {:>14.1}", aot(n, 24, *c, reps));
        }
        println!();
    }

    println!("\n== Fig 8 (bottom): AOT (µs/task) vs worker count, merge-25K ==");
    print!("{:>9}", "workers");
    for c in &combos {
        print!(" {:>14}", c.label());
    }
    println!();
    for w in [24usize, 48, 96, 168, 360, 744] {
        print!("{w:>9}");
        for c in &combos {
            print!(" {:>14.1}", aot(25_000, w, *c, reps));
        }
        println!();
    }
    println!("\npaper: AOT rises with task count for all; with workers only for ws;");
    println!("rsds/ws flat to ~100 workers, then rising; random ~flat throughout");
}
