//! Table II — geometric mean of speedup for experiments A (scheduler) and
//! B (server), baseline Dask/ws, at 1 node (24 workers) and 7 nodes (168).
//!
//! Paper:
//!   dask/random  24w 0.88×   168w 0.95×
//!   rsds/random  24w 1.04×   168w 1.41×
//!   rsds/ws      24w 1.28×   168w 1.66×

use rsds::bench::paper::{reps_from_env, speedups, Combo};
use rsds::graphgen::paper_suite;

fn main() {
    let suite = paper_suite();
    let reps = reps_from_env(3);
    println!("TABLE II — geomean speedups, baseline dask/ws\n");
    println!(
        "{:<8} {:<10} {:>6} {:>8} {:>10} {:>8}",
        "server", "scheduler", "nodes", "workers", "speedup", "paper"
    );
    let combos: [(Combo, [f64; 2]); 3] = [
        (Combo::DASK_RANDOM, [0.88, 0.95]),
        (Combo::RSDS_RANDOM, [1.04, 1.41]),
        (Combo::RSDS_WS, [1.28, 1.66]),
    ];
    for (combo, paper) in combos {
        for (i, nodes) in [1usize, 7].into_iter().enumerate() {
            let s = speedups(&suite, Combo::DASK_WS, combo, nodes, reps, false);
            println!(
                "{:<8} {:<10} {:>6} {:>8} {:>9.2}× {:>7.2}×",
                combo.server,
                combo.scheduler,
                nodes,
                nodes * 24,
                s.geomean,
                paper[i]
            );
        }
    }
}
