//! Hot-path microbenchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): msgpack codec throughput, reactor task-transition rate,
//! scheduler decision latency, and simulator event rate.
//!
//! Targets (DESIGN.md §9): reactor ≥100K transitions/s (≤10 µs/task),
//! codec ≥1 GB/s decode on task messages, ws decision ≤5 µs/task at 1512
//! workers, sim ≥1M events/s.
//!
//! The codec section compares the streaming (zero-copy) codec against the
//! `Value`-tree reference on the per-task hot-path messages, measures
//! allocations per message with a counting global allocator, asserts the
//! zero-allocation guarantees, and emits machine-readable `BENCH_pr2.json`
//! so later PRs have a perf trajectory to compare against.
//!
//! Env knobs: `RSDS_BENCH_QUICK=1` shortens runs (CI smoke);
//! `RSDS_BENCH_SECTION=codec` runs only the codec section.

use rsds::bench::{bench, row, throughput, BenchConfig};
use rsds::graphgen::merge;
use rsds::msgpack::{decode, encode};
use rsds::overhead::RuntimeProfile;
use rsds::protocol::{
    decode_msg, decode_msg_value, encode_msg, encode_msg_into, encode_msg_value,
    ComputeTaskView, Msg, RunId, TaskFinishedInfo, TaskInputLoc,
};
use rsds::scheduler::{self, Action, WorkerId, WorkerInfo};
use rsds::server::{Dest, Origin, Reactor, SchedulerPool};
use rsds::sim::{simulate, SimConfig};
use rsds::taskgraph::TaskId;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// ---------------------------------------------------------------------------
// Counting allocator: every alloc/realloc bumps a counter so the bench can
// report (and assert) allocations per message on the hot path.
// ---------------------------------------------------------------------------

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates directly to the system allocator; the counter is a
// relaxed atomic with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed while running `f`.
fn count_allocs(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

// ---------------------------------------------------------------------------
// Codec micro-bench: streaming vs Value tree, msgs/s and allocs/msg.
// ---------------------------------------------------------------------------

struct CodecRow {
    name: &'static str,
    old_msgs_per_sec: f64,
    new_msgs_per_sec: f64,
    old_allocs_per_msg: f64,
    new_allocs_per_msg: f64,
}

impl CodecRow {
    fn speedup(&self) -> f64 {
        self.new_msgs_per_sec / self.old_msgs_per_sec
    }
}

/// Measure one old/new pair: `old` and `new` each process exactly one
/// message per call.
fn codec_pair(
    cfg: BenchConfig,
    name: &'static str,
    n: u64,
    mut old: impl FnMut(),
    mut new: impl FnMut(),
) -> CodecRow {
    let alloc_iters = 2_000u64;
    // Warm both paths (grows reused buffers to their steady state).
    for _ in 0..100 {
        old();
        new();
    }
    let old_allocs = count_allocs(|| {
        for _ in 0..alloc_iters {
            old();
        }
    }) as f64
        / alloc_iters as f64;
    let new_allocs = count_allocs(|| {
        for _ in 0..alloc_iters {
            new();
        }
    }) as f64
        / alloc_iters as f64;
    let r_old = bench(&format!("codec old: {name}"), cfg, || {
        for _ in 0..n {
            old();
        }
    });
    let r_new = bench(&format!("codec new: {name}"), cfg, || {
        for _ in 0..n {
            new();
        }
    });
    println!(
        "{}   ({:.0} msgs/s, {:.2} allocs/msg)",
        row(&r_old),
        throughput(n, r_old.mean_us()),
        old_allocs
    );
    println!(
        "{}   ({:.0} msgs/s, {:.2} allocs/msg)",
        row(&r_new),
        throughput(n, r_new.mean_us()),
        new_allocs
    );
    CodecRow {
        name,
        old_msgs_per_sec: throughput(n, r_old.mean_us()),
        new_msgs_per_sec: throughput(n, r_new.mean_us()),
        old_allocs_per_msg: old_allocs,
        new_allocs_per_msg: new_allocs,
    }
}

fn codec_section(cfg: BenchConfig) -> Vec<CodecRow> {
    let n: u64 = if std::env::var_os("RSDS_BENCH_QUICK").is_some() { 20_000 } else { 200_000 };
    let mut rows = Vec::new();

    let compute = Msg::ComputeTask {
        run: RunId(7),
        task: TaskId(12345),
        key: "task-12345".into(),
        payload: rsds::taskgraph::Payload::BusyWait,
        duration_us: 6,
        output_size: 28,
        inputs: vec![
            TaskInputLoc { task: TaskId(12_000), addr: "10.0.0.1:9000".into(), nbytes: 512 },
            TaskInputLoc { task: TaskId(12_001), addr: String::new(), nbytes: 64 },
        ],
        priority: 12345,
    };
    let compute_bytes = encode_msg(&compute);
    assert_eq!(compute_bytes, encode_msg_value(&compute), "codecs must agree on bytes");

    let finished = Msg::TaskFinished(TaskFinishedInfo {
        run: RunId(7),
        task: TaskId(12345),
        nbytes: 28,
        duration_us: 6,
    });
    let finished_bytes = encode_msg(&finished);
    let steal = Msg::StealRequest { run: RunId(7), task: TaskId(12345) };
    let steal_bytes = encode_msg(&steal);
    let steal_resp = Msg::StealResponse { run: RunId(7), task: TaskId(12345), ok: true };
    let steal_resp_bytes = encode_msg(&steal_resp);

    // Reused output buffer: the per-connection pattern in the server.
    let mut buf: Vec<u8> = Vec::new();

    // --- encode: assignment / task-finished / steal-request ---
    rows.push(codec_pair(
        cfg,
        "encode compute-task",
        n,
        || {
            std::hint::black_box(encode_msg_value(std::hint::black_box(&compute)));
        },
        || {
            buf.clear();
            encode_msg_into(std::hint::black_box(&compute), &mut buf);
            std::hint::black_box(buf.len());
        },
    ));
    let mut buf: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "encode task-finished",
        n,
        || {
            std::hint::black_box(encode_msg_value(std::hint::black_box(&finished)));
        },
        || {
            buf.clear();
            encode_msg_into(std::hint::black_box(&finished), &mut buf);
            std::hint::black_box(buf.len());
        },
    ));
    let mut buf: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "encode steal-request",
        n,
        || {
            std::hint::black_box(encode_msg_value(std::hint::black_box(&steal)));
        },
        || {
            buf.clear();
            encode_msg_into(std::hint::black_box(&steal), &mut buf);
            std::hint::black_box(buf.len());
        },
    ));

    // --- decode: owned Msg on both sides ---
    rows.push(codec_pair(
        cfg,
        "decode compute-task",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&compute_bytes)).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&compute_bytes)).unwrap());
        },
    ));
    // Borrowed view: the fully zero-allocation decode of the assignment.
    rows.push(codec_pair(
        cfg,
        "decode compute-task (borrowed view)",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&compute_bytes)).unwrap());
        },
        || {
            let v = ComputeTaskView::decode(std::hint::black_box(&compute_bytes)).unwrap();
            std::hint::black_box((v.run, v.task, v.duration_us, v.n_inputs()));
        },
    ));
    rows.push(codec_pair(
        cfg,
        "decode task-finished",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&finished_bytes)).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&finished_bytes)).unwrap());
        },
    ));
    rows.push(codec_pair(
        cfg,
        "decode steal-request",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&steal_bytes)).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&steal_bytes)).unwrap());
        },
    ));
    rows.push(codec_pair(
        cfg,
        "decode steal-response",
        n,
        || {
            let b = std::hint::black_box(&steal_resp_bytes);
            std::hint::black_box(decode_msg_value(b).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&steal_resp_bytes)).unwrap());
        },
    ));

    // --- the acceptance guarantees: zero allocs after warm-up ---
    for r in &rows {
        let zero_alloc_required = matches!(
            r.name,
            "encode compute-task"
                | "encode task-finished"
                | "encode steal-request"
                | "decode compute-task (borrowed view)"
                | "decode task-finished"
                | "decode steal-request"
                | "decode steal-response"
        );
        if zero_alloc_required {
            assert_eq!(
                r.new_allocs_per_msg, 0.0,
                "{}: hot path must be allocation-free after warm-up",
                r.name
            );
        }
    }

    rows
}

fn write_bench_json(rows: &[CodecRow], quick: bool) {
    let geomean = (rows.iter().map(|r| r.speedup().ln()).sum::<f64>() / rows.len() as f64).exp();
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 2,\n");
    json.push_str("  \"bench\": \"codec_micro\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"geomean_speedup\": {geomean:.3},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"old_msgs_per_sec\": {:.0}, \"new_msgs_per_sec\": {:.0}, \
             \"speedup\": {:.3}, \"old_allocs_per_msg\": {:.2}, \"new_allocs_per_msg\": {:.2}}}{}\n",
            r.name,
            r.old_msgs_per_sec,
            r.new_msgs_per_sec,
            r.speedup(),
            r.old_allocs_per_msg,
            r.new_allocs_per_msg,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr2.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr2.json (geomean speedup {geomean:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_pr2.json: {e}"),
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let section = std::env::var("RSDS_BENCH_SECTION").unwrap_or_default();

    // --- streaming vs Value-tree codec on hot-path messages ---
    println!("== codec: streaming vs Value tree (old vs new) ==");
    let rows = codec_section(cfg);
    for r in &rows {
        println!(
            "{:<40} {:>8.2}x msgs/s   allocs/msg {:.2} -> {:.2}",
            r.name,
            r.speedup(),
            r.old_allocs_per_msg,
            r.new_allocs_per_msg
        );
    }
    write_bench_json(&rows, quick);
    if section == "codec" {
        return;
    }

    // --- raw msgpack on a 1 MiB binary payload (data-plane shape) ---
    let big = rsds::msgpack::Value::map(vec![
        ("op", rsds::msgpack::Value::str("data-reply")),
        ("task", rsds::msgpack::Value::Int(1)),
        ("data", rsds::msgpack::Value::Bin(vec![0xAB; 1 << 20])),
    ]);
    let big_bytes = encode(&big);
    let r = bench("msgpack: decode 1 MiB binary message", cfg, || {
        std::hint::black_box(decode(std::hint::black_box(&big_bytes)).unwrap());
    });
    println!("{}   ({:.2} GB/s)", row(&r), big_bytes.len() as f64 / r.mean_us() / 1e3);

    // --- reactor: drive merge-10K to completion with inline finishes ---
    let r = bench("reactor: merge-10K full graph turnaround", cfg, || {
        let mut reactor = Reactor::new(
            SchedulerPool::new("ws", 1).unwrap(),
            RuntimeProfile::rust(),
            false,
        );
        let mut out = Vec::new();
        reactor.on_message(
            Origin::Unregistered { conn: 0 },
            Msg::RegisterClient { name: "b".into() },
            &mut out,
        );
        for i in 0..24u32 {
            reactor.on_message(
                Origin::Unregistered { conn: 1 + i as u64 },
                Msg::RegisterWorker {
                    name: format!("w{i}"),
                    ncores: 1,
                    node: 0,
                    data_addr: String::new(),
                },
                &mut out,
            );
        }
        out.clear();
        reactor.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(10_000), scheduler: None },
            &mut out,
        );
        // Answer every compute/steal message until done (drain emits the
        // fairness-parked worker-bound messages).
        reactor.drain(&mut out);
        let mut inbox: Vec<(Dest, Msg)> = std::mem::take(&mut out);
        while let Some((dest, msg)) = inbox.pop() {
            let Dest::Worker(w) = dest else { continue };
            match msg {
                Msg::ComputeTask { run, task, output_size, .. } => reactor.on_message(
                    Origin::Worker(w),
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 6,
                    }),
                    &mut out,
                ),
                Msg::StealRequest { run, task } => reactor.on_message(
                    Origin::Worker(w),
                    Msg::StealResponse { run, task, ok: false },
                    &mut out,
                ),
                _ => {}
            }
            reactor.drain(&mut out);
            inbox.append(&mut out);
        }
        assert_eq!(reactor.reports().len(), 1);
    });
    println!("{}   ({:.0} tasks/s)", row(&r), throughput(10_001, r.mean_us()));

    // --- scheduler decision latency at paper-scale clusters ---
    for workers in [24usize, 1512] {
        for sched_name in ["ws", "dask-ws", "random"] {
            let graph = merge(10_000);
            let ready: Vec<TaskId> = graph.roots();
            let r = bench(
                &format!("scheduler {sched_name}: 10k decisions @ {workers} workers"),
                cfg,
                || {
                    let mut s = scheduler::by_name(sched_name, 1).unwrap();
                    for i in 0..workers as u32 {
                        s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i / 24 });
                    }
                    s.graph_submitted(&graph);
                    let mut out: Vec<Action> = Vec::new();
                    s.tasks_ready(&ready, &mut out);
                    std::hint::black_box(out.len());
                },
            );
            println!("{}   ({:.2} µs/decision)", row(&r), r.mean_us() / 10_000.0);
        }
    }

    // --- simulator event rate ---
    let graph = merge(50_000);
    let r = bench("sim: merge-50K @ 168 workers (rsds/ws)", cfg, || {
        let c = SimConfig::nodes(7, RuntimeProfile::rust(), "ws");
        std::hint::black_box(simulate(&graph, &c).makespan_us);
    });
    // ~6 events per task (arrive, wake, done, status, sched, assign).
    let events = 50_001.0 * 6.0;
    println!("{}   (~{:.2} M events/s)", row(&r), events / r.mean_us());
}
