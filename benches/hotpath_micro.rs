//! Hot-path microbenchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): msgpack codec throughput, reactor task-transition rate,
//! scheduler decision latency, and simulator event rate.
//!
//! Targets (DESIGN.md §9): reactor ≥100K transitions/s (≤10 µs/task),
//! codec ≥1 GB/s decode on task messages, ws decision ≤5 µs/task at 1512
//! workers, sim ≥1M events/s.
//!
//! The codec section compares the streaming (zero-copy) codec against the
//! `Value`-tree reference on the per-task hot-path messages, measures
//! allocations per message with a counting global allocator, asserts the
//! zero-allocation guarantees, and emits machine-readable `BENCH_pr2.json`
//! so later PRs have a perf trajectory to compare against.
//!
//! The dispatch section (PR 5) does the same for the *ends* of the
//! per-task path the codec sits between: server assignment → outbound
//! frame (owned `Msg` vs borrowed `ComputeDispatch`) and worker frame →
//! priority queue → pop (owned decode vs interned `TaskQueue`). It asserts
//! 0 allocs/task on both warm paths and emits `BENCH_pr5.json`.
//!
//! The dataplane section (PR 10) covers the worker↔worker serve path:
//! the old owned reply (clone the stored payload into `Msg::DataReply`,
//! encode the whole message) vs the borrowed split encode the data
//! server streams (head + `Arc` payload segment + tail into reused
//! buffers), and the old connect-per-object fetch request loop vs one
//! batched `fetch-data-many`. Both new paths must be allocation-free
//! per object after warm-up — the PR 10 zero-copy gate. Emits
//! `BENCH_pr10_micro.json`.
//!
//! Env knobs: `RSDS_BENCH_QUICK=1` shortens runs (CI smoke);
//! `RSDS_BENCH_SECTION=codec|dispatch|dataplane` runs one section only.

use rsds::bench::{bench, row, throughput, BenchConfig};
use rsds::graphgen::merge;
use rsds::msgpack::{decode, encode};
use rsds::overhead::RuntimeProfile;
use rsds::protocol::{
    append_frame, append_frame_with, decode_msg, decode_msg_value, encode_data_frame_head,
    encode_data_frame_tail, encode_fetch_many_into, encode_msg, encode_msg_into, encode_msg_value,
    ComputeTaskView, DataFrameParts, Msg, RunId, TaskFinishedInfo, TaskInputLoc,
};
use rsds::scheduler::{self, Action, WorkerId, WorkerInfo};
use rsds::server::{ComputeDispatch, Dest, GraphRun, Origin, Reactor, ReplicaSet, SchedulerPool};
use rsds::sim::{simulate, SimConfig};
use rsds::taskgraph::{GraphBuilder, Payload, TaskId};
use rsds::worker::queue::{FetchPlan, TaskQueue};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// ---------------------------------------------------------------------------
// Counting allocator: every alloc/realloc bumps a counter so the bench can
// report (and assert) allocations per message on the hot path.
// ---------------------------------------------------------------------------

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates directly to the system allocator; the counter is a
// relaxed atomic with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed while running `f`.
fn count_allocs(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    ALLOCS.load(Ordering::Relaxed) - before
}

// ---------------------------------------------------------------------------
// Codec micro-bench: streaming vs Value tree, msgs/s and allocs/msg.
// ---------------------------------------------------------------------------

struct CodecRow {
    name: &'static str,
    old_msgs_per_sec: f64,
    new_msgs_per_sec: f64,
    old_allocs_per_msg: f64,
    new_allocs_per_msg: f64,
}

impl CodecRow {
    fn speedup(&self) -> f64 {
        self.new_msgs_per_sec / self.old_msgs_per_sec
    }
}

/// Measure one old/new pair: `old` and `new` each process exactly one
/// message per call.
fn codec_pair(
    cfg: BenchConfig,
    name: &'static str,
    n: u64,
    mut old: impl FnMut(),
    mut new: impl FnMut(),
) -> CodecRow {
    let alloc_iters = 2_000u64;
    // Warm both paths (grows reused buffers to their steady state).
    for _ in 0..100 {
        old();
        new();
    }
    let old_allocs = count_allocs(|| {
        for _ in 0..alloc_iters {
            old();
        }
    }) as f64
        / alloc_iters as f64;
    let new_allocs = count_allocs(|| {
        for _ in 0..alloc_iters {
            new();
        }
    }) as f64
        / alloc_iters as f64;
    let r_old = bench(&format!("codec old: {name}"), cfg, || {
        for _ in 0..n {
            old();
        }
    });
    let r_new = bench(&format!("codec new: {name}"), cfg, || {
        for _ in 0..n {
            new();
        }
    });
    println!(
        "{}   ({:.0} msgs/s, {:.2} allocs/msg)",
        row(&r_old),
        throughput(n, r_old.mean_us()),
        old_allocs
    );
    println!(
        "{}   ({:.0} msgs/s, {:.2} allocs/msg)",
        row(&r_new),
        throughput(n, r_new.mean_us()),
        new_allocs
    );
    CodecRow {
        name,
        old_msgs_per_sec: throughput(n, r_old.mean_us()),
        new_msgs_per_sec: throughput(n, r_new.mean_us()),
        old_allocs_per_msg: old_allocs,
        new_allocs_per_msg: new_allocs,
    }
}

fn codec_section(cfg: BenchConfig) -> Vec<CodecRow> {
    let n: u64 = if std::env::var_os("RSDS_BENCH_QUICK").is_some() { 20_000 } else { 200_000 };
    let mut rows = Vec::new();

    let compute = Msg::ComputeTask {
        run: RunId(7),
        task: TaskId(12345),
        key: "task-12345".into(),
        payload: rsds::taskgraph::Payload::BusyWait,
        duration_us: 6,
        output_size: 28,
        inputs: vec![
            TaskInputLoc {
                task: TaskId(12_000),
                addr: "10.0.0.1:9000".into(),
                alts: vec!["10.0.0.2:9000".into()],
                nbytes: 512,
            },
            TaskInputLoc {
                task: TaskId(12_001),
                addr: String::new(),
                alts: vec![],
                nbytes: 64,
            },
        ],
        priority: 12345,
        consumers: 2,
        cores: 1,
    };
    let compute_bytes = encode_msg(&compute);
    assert_eq!(compute_bytes, encode_msg_value(&compute), "codecs must agree on bytes");

    let finished = Msg::TaskFinished(TaskFinishedInfo {
        run: RunId(7),
        task: TaskId(12345),
        nbytes: 28,
        duration_us: 6,
    });
    let finished_bytes = encode_msg(&finished);
    let steal = Msg::StealRequest { run: RunId(7), task: TaskId(12345) };
    let steal_bytes = encode_msg(&steal);
    let steal_resp = Msg::StealResponse { run: RunId(7), task: TaskId(12345), ok: true };
    let steal_resp_bytes = encode_msg(&steal_resp);

    // Reused output buffer: the per-connection pattern in the server.
    let mut buf: Vec<u8> = Vec::new();

    // --- encode: assignment / task-finished / steal-request ---
    rows.push(codec_pair(
        cfg,
        "encode compute-task",
        n,
        || {
            std::hint::black_box(encode_msg_value(std::hint::black_box(&compute)));
        },
        || {
            buf.clear();
            encode_msg_into(std::hint::black_box(&compute), &mut buf);
            std::hint::black_box(buf.len());
        },
    ));
    let mut buf: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "encode task-finished",
        n,
        || {
            std::hint::black_box(encode_msg_value(std::hint::black_box(&finished)));
        },
        || {
            buf.clear();
            encode_msg_into(std::hint::black_box(&finished), &mut buf);
            std::hint::black_box(buf.len());
        },
    ));
    let mut buf: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "encode steal-request",
        n,
        || {
            std::hint::black_box(encode_msg_value(std::hint::black_box(&steal)));
        },
        || {
            buf.clear();
            encode_msg_into(std::hint::black_box(&steal), &mut buf);
            std::hint::black_box(buf.len());
        },
    ));

    // --- decode: owned Msg on both sides ---
    rows.push(codec_pair(
        cfg,
        "decode compute-task",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&compute_bytes)).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&compute_bytes)).unwrap());
        },
    ));
    // Borrowed view: the fully zero-allocation decode of the assignment.
    rows.push(codec_pair(
        cfg,
        "decode compute-task (borrowed view)",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&compute_bytes)).unwrap());
        },
        || {
            let v = ComputeTaskView::decode(std::hint::black_box(&compute_bytes)).unwrap();
            std::hint::black_box((v.run, v.task, v.duration_us, v.n_inputs()));
        },
    ));
    rows.push(codec_pair(
        cfg,
        "decode task-finished",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&finished_bytes)).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&finished_bytes)).unwrap());
        },
    ));
    rows.push(codec_pair(
        cfg,
        "decode steal-request",
        n,
        || {
            std::hint::black_box(decode_msg_value(std::hint::black_box(&steal_bytes)).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&steal_bytes)).unwrap());
        },
    ));
    rows.push(codec_pair(
        cfg,
        "decode steal-response",
        n,
        || {
            let b = std::hint::black_box(&steal_resp_bytes);
            std::hint::black_box(decode_msg_value(b).unwrap());
        },
        || {
            std::hint::black_box(decode_msg(std::hint::black_box(&steal_resp_bytes)).unwrap());
        },
    ));

    // --- the acceptance guarantees: zero allocs after warm-up ---
    for r in &rows {
        let zero_alloc_required = matches!(
            r.name,
            "encode compute-task"
                | "encode task-finished"
                | "encode steal-request"
                | "decode compute-task (borrowed view)"
                | "decode task-finished"
                | "decode steal-request"
                | "decode steal-response"
        );
        if zero_alloc_required {
            assert_eq!(
                r.new_allocs_per_msg, 0.0,
                "{}: hot path must be allocation-free after warm-up",
                r.name
            );
        }
    }

    rows
}

// ---------------------------------------------------------------------------
// Dispatch micro (PR 5): the interned per-task path, old-vs-new.
//
// Server side: parked assignment → outbound frame. Old = materialize the
// owned Msg::ComputeTask (key clone + input Vec + addr Strings — the PR 2
// dispatch) and encode it; new = encode the borrowed ComputeDispatch
// straight into the batch buffer.
//
// Worker side: frame → priority queue → pop. Old = owned decode_msg and an
// owned queue entry; new = borrowed ComputeTaskView interned into the
// run-local arenas (TaskQueue::enqueue) and popped into reused scratch.
//
// Both new paths must be allocation-free after warm-up — the PR 5
// acceptance gate, asserted below under the counting allocator.
// ---------------------------------------------------------------------------

fn dispatch_section(cfg: BenchConfig) -> Vec<CodecRow> {
    let n: u64 = if std::env::var_os("RSDS_BENCH_QUICK").is_some() { 20_000 } else { 200_000 };
    let mut rows = Vec::new();

    // A dependency-bearing run, as the reactor holds it: two finished
    // leaves (one remote, one local to the target) feeding a sink task.
    let mut b = GraphBuilder::new();
    let leaf_a = b.add("leaf-a", vec![], 5, 512, Payload::BusyWait);
    let leaf_b = b.add("leaf-b", vec![], 5, 64, Payload::BusyWait);
    let sink = b.add("sink-0", vec![leaf_a, leaf_b], 6, 28, Payload::BusyWait);
    let graph = b.build("dispatch").unwrap();
    let mut run = GraphRun::new(graph, 0, 0);
    run.who_has[leaf_a.idx()].push(WorkerId(1));
    run.who_has[leaf_b.idx()].push(WorkerId(0));
    let addrs: Vec<String> = vec!["10.0.0.1:9000".into(), "10.0.0.2:9000".into()];
    let run_id = RunId(7);

    let mut batch_old: Vec<u8> = Vec::new();
    let mut batch_new: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "server dispatch: assignment -> frame",
        n,
        || {
            batch_old.clear();
            let d = ComputeDispatch::new(run_id, sink, WorkerId(0), 3, &run, &addrs);
            let msg = d.to_msg(); // the pre-interning path: owned message first
            append_frame(&mut batch_old, &msg).unwrap();
            std::hint::black_box(batch_old.len());
        },
        || {
            batch_new.clear();
            let d = ComputeDispatch::new(run_id, sink, WorkerId(0), 3, &run, &addrs);
            append_frame_with(&mut batch_new, |body| d.encode_into(body)).unwrap();
            std::hint::black_box(batch_new.len());
        },
    ));
    assert_eq!(batch_old, batch_new, "borrowed dispatch must stay byte-identical");

    // The frame body the worker receives (length prefix stripped).
    let frame_body: Vec<u8> = batch_new[8..].to_vec();

    // Old worker enqueue: owned decode + owned queue entry (String key,
    // Vec<TaskInputLoc>), mirroring the pre-PR5 QueuedTask.
    struct OldQueued {
        #[allow(dead_code)]
        priority: i64,
        #[allow(dead_code)]
        key: String,
        #[allow(dead_code)]
        inputs: Vec<TaskInputLoc>,
    }
    let mut old_heap: Vec<OldQueued> = Vec::new();
    let mut q = TaskQueue::new();
    let mut plan = FetchPlan::new();
    rows.push(codec_pair(
        cfg,
        "worker enqueue: frame -> queue -> pop",
        n,
        || {
            let Msg::ComputeTask { key, inputs, priority, .. } =
                decode_msg(std::hint::black_box(&frame_body)).unwrap()
            else {
                unreachable!()
            };
            old_heap.push(OldQueued { priority, key, inputs });
            std::hint::black_box(old_heap.pop());
        },
        || {
            let view = ComputeTaskView::decode(std::hint::black_box(&frame_body)).unwrap();
            q.enqueue(&view).unwrap();
            std::hint::black_box(q.pop_into(&mut plan).is_some());
        },
    ));

    // Replica bookkeeping (PR 7): the reactor's per-task `who_has` entry.
    // Old = a fresh heap Vec<WorkerId> per finish (1 alloc); new = the
    // inline ReplicaSet — push on finish, first() on dispatch, retain() on
    // a worker death — allocation-free at the common replication factor.
    rows.push(codec_pair(
        cfg,
        "who_has: finish -> dispatch -> death",
        n,
        || {
            let mut h: Vec<WorkerId> = Vec::with_capacity(2);
            h.push(WorkerId(0));
            h.push(WorkerId(1));
            std::hint::black_box(h.first().copied());
            h.retain(|&w| w != WorkerId(0));
            std::hint::black_box(h.len());
        },
        || {
            let mut h = ReplicaSet::new();
            h.push(WorkerId(0));
            h.push(WorkerId(1));
            std::hint::black_box(h.first());
            h.retain(|w| w != WorkerId(0));
            std::hint::black_box(h.len());
        },
    ));

    // --- the PR 5/7 acceptance gate: 0 allocs/task after warm-up ---
    for r in &rows {
        assert_eq!(
            r.new_allocs_per_msg, 0.0,
            "{}: the interned path must be allocation-free after warm-up",
            r.name
        );
    }
    rows
}

// ---------------------------------------------------------------------------
// Dataplane micro (PR 10): the zero-copy serve-encode path, old-vs-new.
//
// Serve side: store hit → wire frame. Old = clone the stored payload out
// of its Arc into an owned Msg::DataReply and encode the whole message
// (the pre-PR10 `serve_data_conn`/`push_one` shape: one full payload copy
// plus an output buffer per object). New = the split borrowed encode the
// poll-driven data server streams: 8-byte length prefix + frame head into
// a reused buffer, the payload segment as an Arc refcount bump, the tail
// into a second reused buffer.
//
// Fetch side: gather request encode. Old = one owned Msg::FetchData per
// object; new = a single batched fetch-data-many into a reused buffer.
//
// Both new paths must be allocation-free per object after warm-up — the
// PR 10 acceptance gate, asserted below under the counting allocator.
// ---------------------------------------------------------------------------

fn dataplane_section(cfg: BenchConfig) -> Vec<CodecRow> {
    let n: u64 = if std::env::var_os("RSDS_BENCH_QUICK").is_some() { 20_000 } else { 200_000 };
    let mut rows = Vec::new();

    let run = RunId(7);
    let task = TaskId(12345);
    let payload: std::sync::Arc<Vec<u8>> = std::sync::Arc::new(vec![0xAB; 64 * 1024]);

    // Byte-identity of the split encode against the owned message, with
    // the frame prefix stripped (checked once, outside the timed loops).
    let owned_bytes = encode_msg(&Msg::DataReply {
        run,
        task,
        data: payload.as_ref().clone(),
    });
    let parts = DataFrameParts { op: "data-reply", run, task, data_len: payload.len() };
    let mut split = Vec::new();
    encode_data_frame_head(&parts, &mut split);
    split.extend_from_slice(&payload);
    encode_data_frame_tail(&parts, &mut split);
    assert_eq!(owned_bytes, split, "split serve encode must stay byte-identical");

    // Reused per-connection buffers: the OutQueue steady state.
    let mut head: Vec<u8> = Vec::new();
    let mut tail: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "serve: store hit -> reply frame",
        n,
        || {
            let msg = Msg::DataReply {
                run,
                task,
                data: std::hint::black_box(&payload).as_ref().clone(),
            };
            std::hint::black_box(encode_msg(&msg).len());
        },
        || {
            let p = DataFrameParts {
                op: "data-reply",
                run,
                task,
                data_len: std::hint::black_box(&payload).len(),
            };
            head.clear();
            head.extend_from_slice(&[0u8; 8]);
            encode_data_frame_head(&p, &mut head);
            tail.clear();
            encode_data_frame_tail(&p, &mut tail);
            let frame_len = (head.len() - 8 + payload.len() + tail.len()) as u64;
            head[..8].copy_from_slice(&frame_len.to_le_bytes());
            // The payload segment goes to the socket straight from the
            // store's Arc — a refcount bump, never a copy.
            let seg = payload.clone();
            std::hint::black_box((head.len(), seg.len(), tail.len()));
        },
    ));

    // A 16-object gather request to one peer.
    let tasks: Vec<TaskId> = (0..16u32).map(TaskId).collect();
    let mut req: Vec<u8> = Vec::new();
    rows.push(codec_pair(
        cfg,
        "gather request: 16 objects -> wire",
        n,
        || {
            for &t in std::hint::black_box(&tasks) {
                std::hint::black_box(encode_msg(&Msg::FetchData { run, task: t }).len());
            }
        },
        || {
            req.clear();
            encode_fetch_many_into(run, std::hint::black_box(&tasks), &mut req);
            std::hint::black_box(req.len());
        },
    ));

    // --- the PR 10 acceptance gate: 0 allocs/object after warm-up ---
    for r in &rows {
        assert_eq!(
            r.new_allocs_per_msg, 0.0,
            "{}: the zero-copy path must be allocation-free after warm-up",
            r.name
        );
    }
    rows
}

fn write_bench_json(path: &str, pr: u32, bench_name: &str, rows: &[CodecRow], quick: bool) {
    let geomean = (rows.iter().map(|r| r.speedup().ln()).sum::<f64>() / rows.len() as f64).exp();
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"pr\": {pr},\n"));
    json.push_str(&format!("  \"bench\": \"{bench_name}\",\n"));
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"geomean_speedup\": {geomean:.3},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"old_msgs_per_sec\": {:.0}, \"new_msgs_per_sec\": {:.0}, \
             \"speedup\": {:.3}, \"old_allocs_per_msg\": {:.2}, \"new_allocs_per_msg\": {:.2}}}{}\n",
            r.name,
            r.old_msgs_per_sec,
            r.new_msgs_per_sec,
            r.speedup(),
            r.old_allocs_per_msg,
            r.new_allocs_per_msg,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path} (geomean speedup {geomean:.2}x)"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn print_rows(rows: &[CodecRow]) {
    for r in rows {
        println!(
            "{:<40} {:>8.2}x msgs/s   allocs/msg {:.2} -> {:.2}",
            r.name,
            r.speedup(),
            r.old_allocs_per_msg,
            r.new_allocs_per_msg
        );
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let section = std::env::var("RSDS_BENCH_SECTION").unwrap_or_default();

    // --- streaming vs Value-tree codec on hot-path messages ---
    if section.is_empty() || section == "codec" {
        println!("== codec: streaming vs Value tree (old vs new) ==");
        let rows = codec_section(cfg);
        print_rows(&rows);
        write_bench_json("BENCH_pr2.json", 2, "codec_micro", &rows, quick);
    }
    // --- interned dispatch + worker enqueue (PR 5 tentpole gate) ---
    if section.is_empty() || section == "dispatch" {
        println!("\n== dispatch: interned per-task path (old vs new) ==");
        let rows = dispatch_section(cfg);
        print_rows(&rows);
        write_bench_json("BENCH_pr5.json", 5, "dispatch_micro", &rows, quick);
    }
    // --- zero-copy serve encode + batched fetch (PR 10 tentpole gate) ---
    if section.is_empty() || section == "dataplane" {
        println!("\n== dataplane: zero-copy serve path (old vs new) ==");
        let rows = dataplane_section(cfg);
        print_rows(&rows);
        write_bench_json("BENCH_pr10_micro.json", 10, "dataplane_micro", &rows, quick);
    }
    if !section.is_empty() {
        return;
    }

    // --- raw msgpack on a 1 MiB binary payload (data-plane shape) ---
    let big = rsds::msgpack::Value::map(vec![
        ("op", rsds::msgpack::Value::str("data-reply")),
        ("task", rsds::msgpack::Value::Int(1)),
        ("data", rsds::msgpack::Value::Bin(vec![0xAB; 1 << 20])),
    ]);
    let big_bytes = encode(&big);
    let r = bench("msgpack: decode 1 MiB binary message", cfg, || {
        std::hint::black_box(decode(std::hint::black_box(&big_bytes)).unwrap());
    });
    println!("{}   ({:.2} GB/s)", row(&r), big_bytes.len() as f64 / r.mean_us() / 1e3);

    // --- reactor: drive merge-10K to completion with inline finishes ---
    let r = bench("reactor: merge-10K full graph turnaround", cfg, || {
        let mut reactor = Reactor::new(
            SchedulerPool::new("ws", 1).unwrap(),
            RuntimeProfile::rust(),
            false,
        );
        let mut out = Vec::new();
        reactor.on_message(
            Origin::Unregistered { conn: 0 },
            Msg::RegisterClient { name: "b".into() },
            &mut out,
        );
        for i in 0..24u32 {
            reactor.on_message(
                Origin::Unregistered { conn: 1 + i as u64 },
                Msg::RegisterWorker {
                    name: format!("w{i}"),
                    ncores: 1,
                    node: 0,
                    data_addr: String::new(),
                },
                &mut out,
            );
        }
        out.clear();
        reactor.on_message(
            Origin::Client(0),
            Msg::SubmitGraph { graph: merge(10_000), scheduler: None, open: false },
            &mut out,
        );
        // Answer every compute/steal message until done (drain emits the
        // fairness-parked worker-bound messages).
        reactor.drain(&mut out);
        let mut inbox: Vec<(Dest, Msg)> = std::mem::take(&mut out);
        while let Some((dest, msg)) = inbox.pop() {
            let Dest::Worker(w) = dest else { continue };
            match msg {
                Msg::ComputeTask { run, task, output_size, .. } => reactor.on_message(
                    Origin::Worker(w),
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 6,
                    }),
                    &mut out,
                ),
                Msg::StealRequest { run, task } => reactor.on_message(
                    Origin::Worker(w),
                    Msg::StealResponse { run, task, ok: false },
                    &mut out,
                ),
                _ => {}
            }
            reactor.drain(&mut out);
            inbox.append(&mut out);
        }
        assert_eq!(reactor.reports().len(), 1);
    });
    println!("{}   ({:.0} tasks/s)", row(&r), throughput(10_001, r.mean_us()));

    // --- scheduler decision latency at paper-scale clusters ---
    for workers in [24usize, 1512] {
        for sched_name in ["ws", "dask-ws", "random"] {
            let graph = merge(10_000);
            let ready: Vec<TaskId> = graph.roots();
            let r = bench(
                &format!("scheduler {sched_name}: 10k decisions @ {workers} workers"),
                cfg,
                || {
                    let mut s = scheduler::by_name(sched_name, 1).unwrap();
                    for i in 0..workers as u32 {
                        s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i / 24 });
                    }
                    s.graph_submitted(&graph);
                    let mut out: Vec<Action> = Vec::new();
                    s.tasks_ready(&ready, &mut out);
                    std::hint::black_box(out.len());
                },
            );
            println!("{}   ({:.2} µs/decision)", row(&r), r.mean_us() / 10_000.0);
        }
    }

    // --- simulator event rate ---
    let graph = merge(50_000);
    let r = bench("sim: merge-50K @ 168 workers (rsds/ws)", cfg, || {
        let c = SimConfig::nodes(7, RuntimeProfile::rust(), "ws");
        std::hint::black_box(simulate(&graph, &c).makespan_us);
    });
    // ~6 events per task (arrive, wake, done, status, sched, assign).
    let events = 50_001.0 * 6.0;
    println!("{}   (~{:.2} M events/s)", row(&r), events / r.mean_us());
}
