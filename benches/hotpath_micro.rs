//! Hot-path microbenchmarks (the §Perf baseline/after numbers in
//! EXPERIMENTS.md): msgpack codec throughput, reactor task-transition rate,
//! scheduler decision latency, and simulator event rate.
//!
//! Targets (DESIGN.md §9): reactor ≥100K transitions/s (≤10 µs/task),
//! codec ≥1 GB/s decode on task messages, ws decision ≤5 µs/task at 1512
//! workers, sim ≥1M events/s.

use rsds::bench::{bench, row, throughput, BenchConfig};
use rsds::graphgen::merge;
use rsds::msgpack::{decode, encode};
use rsds::overhead::RuntimeProfile;
use rsds::protocol::{decode_msg, encode_msg, Msg, RunId, TaskFinishedInfo};
use rsds::scheduler::{self, Action, WorkerId, WorkerInfo};
use rsds::server::{Dest, Origin, Reactor, SchedulerPool};
use rsds::sim::{simulate, SimConfig};
use rsds::taskgraph::TaskId;

fn main() {
    let cfg = BenchConfig::from_env();

    // --- msgpack codec on a compute-task-shaped message ---
    let msg = Msg::ComputeTask {
        run: RunId(7),
        task: TaskId(12345),
        key: "task-12345".into(),
        payload: rsds::taskgraph::Payload::BusyWait,
        duration_us: 6,
        output_size: 28,
        inputs: vec![],
        priority: 12345,
    };
    let bytes = encode_msg(&msg);
    let n = 10_000;
    let r = bench("protocol: encode 10k compute-task msgs", cfg, || {
        for _ in 0..n {
            std::hint::black_box(encode_msg(std::hint::black_box(&msg)));
        }
    });
    println!("{}   ({:.0} msgs/s)", row(&r), throughput(n, r.mean_us()));
    let r = bench("protocol: decode 10k compute-task msgs", cfg, || {
        for _ in 0..n {
            std::hint::black_box(decode_msg(std::hint::black_box(&bytes)).unwrap());
        }
    });
    println!(
        "{}   ({:.0} msgs/s, {:.2} MB/s)",
        row(&r),
        throughput(n, r.mean_us()),
        (n as f64 * bytes.len() as f64) / r.mean_us()
    );

    // --- raw msgpack on a 1 MiB binary payload (data-plane shape) ---
    let big = rsds::msgpack::Value::map(vec![
        ("op", rsds::msgpack::Value::str("data-reply")),
        ("task", rsds::msgpack::Value::Int(1)),
        ("data", rsds::msgpack::Value::Bin(vec![0xAB; 1 << 20])),
    ]);
    let big_bytes = encode(&big);
    let r = bench("msgpack: decode 1 MiB binary message", cfg, || {
        std::hint::black_box(decode(std::hint::black_box(&big_bytes)).unwrap());
    });
    println!("{}   ({:.2} GB/s)", row(&r), big_bytes.len() as f64 / r.mean_us() / 1e3);

    // --- reactor: drive merge-10K to completion with inline finishes ---
    let r = bench("reactor: merge-10K full graph turnaround", cfg, || {
        let mut reactor = Reactor::new(
            SchedulerPool::new("ws", 1).unwrap(),
            RuntimeProfile::rust(),
            false,
        );
        let mut out = Vec::new();
        reactor.on_message(
            Origin::Unregistered { conn: 0 },
            Msg::RegisterClient { name: "b".into() },
            &mut out,
        );
        for i in 0..24u32 {
            reactor.on_message(
                Origin::Unregistered { conn: 1 + i as u64 },
                Msg::RegisterWorker {
                    name: format!("w{i}"),
                    ncores: 1,
                    node: 0,
                    data_addr: String::new(),
                },
                &mut out,
            );
        }
        out.clear();
        reactor.on_message(Origin::Client(0), Msg::SubmitGraph { graph: merge(10_000) }, &mut out);
        // Answer every compute/steal message until done.
        let mut inbox: Vec<(Dest, Msg)> = std::mem::take(&mut out);
        while let Some((dest, msg)) = inbox.pop() {
            let Dest::Worker(w) = dest else { continue };
            match msg {
                Msg::ComputeTask { run, task, output_size, .. } => reactor.on_message(
                    Origin::Worker(w),
                    Msg::TaskFinished(TaskFinishedInfo {
                        run,
                        task,
                        nbytes: output_size,
                        duration_us: 6,
                    }),
                    &mut out,
                ),
                Msg::StealRequest { run, task } => reactor.on_message(
                    Origin::Worker(w),
                    Msg::StealResponse { run, task, ok: false },
                    &mut out,
                ),
                _ => {}
            }
            inbox.append(&mut out);
        }
        assert_eq!(reactor.reports().len(), 1);
    });
    println!("{}   ({:.0} tasks/s)", row(&r), throughput(10_001, r.mean_us()));

    // --- scheduler decision latency at paper-scale clusters ---
    for workers in [24usize, 1512] {
        for sched_name in ["ws", "dask-ws", "random"] {
            let graph = merge(10_000);
            let ready: Vec<TaskId> = graph.roots();
            let r = bench(
                &format!("scheduler {sched_name}: 10k decisions @ {workers} workers"),
                cfg,
                || {
                    let mut s = scheduler::by_name(sched_name, 1).unwrap();
                    for i in 0..workers as u32 {
                        s.add_worker(WorkerInfo { id: WorkerId(i), ncores: 1, node: i / 24 });
                    }
                    s.graph_submitted(&graph);
                    let mut out: Vec<Action> = Vec::new();
                    s.tasks_ready(&ready, &mut out);
                    std::hint::black_box(out.len());
                },
            );
            println!("{}   ({:.2} µs/decision)", row(&r), r.mean_us() / 10_000.0);
        }
    }

    // --- simulator event rate ---
    let graph = merge(50_000);
    let r = bench("sim: merge-50K @ 168 workers (rsds/ws)", cfg, || {
        let c = SimConfig::nodes(7, RuntimeProfile::rust(), "ws");
        std::hint::black_box(simulate(&graph, &c).makespan_us);
    });
    // ~6 events per task (arrive, wake, done, status, sched, assign).
    let events = 50_001.0 * 6.0;
    println!("{}   (~{:.2} M events/s)", row(&r), events / r.mean_us());
}
