//! Fig 7 — average runtime overhead per task (AOT = makespan / #tasks)
//! under the zero worker, per benchmark and cluster size, for all four
//! server/scheduler combinations.
//!
//! Paper shape: "the overhead is less than 1 ms for most of our
//! benchmarks" on Dask; RSDS sits well below on every configuration.

use rsds::bench::paper::{measure, reps_from_env, Combo};
use rsds::graphgen::suite_subset_zero_worker;

fn main() {
    let reps = reps_from_env(3);
    let combos = [Combo::DASK_WS, Combo::DASK_RANDOM, Combo::RSDS_WS, Combo::RSDS_RANDOM];
    for nodes in [1usize, 7] {
        println!("\n== Fig 7: AOT (µs/task) under zero worker, {} workers ==", nodes * 24);
        print!("{:<28}", "benchmark");
        for c in &combos {
            print!(" {:>14}", c.label());
        }
        println!();
        for entry in suite_subset_zero_worker() {
            print!("{:<28}", entry.name);
            for combo in &combos {
                let m = measure(&entry, *combo, nodes, reps, true);
                print!(" {:>14.1}", m.aot_us);
            }
            println!();
        }
    }
    println!("\npaper: Dask < 1000 µs/task for most benchmarks; RSDS far below Dask everywhere");
}
