//! Fig 6 — speedup of RSDS/ws over Dask/ws when the **zero worker** (§IV-D)
//! replaces real workers, isolating pure server overhead. Uses the
//! zero-worker-safe subset of the suite (§VI-D excludes graphs whose tasks
//! depend on concrete output values).
//!
//! Paper shape: RSDS is 1.1–6× faster — a larger gap than with real
//! workers, since the server is the only bottleneck left.

use rsds::bench::paper::{print_speedups, reps_from_env, speedups, Combo};
use rsds::graphgen::suite_subset_zero_worker;

fn main() {
    let suite = suite_subset_zero_worker();
    let reps = reps_from_env(3);
    for nodes in [1usize, 7] {
        let series = speedups(&suite, Combo::DASK_WS, Combo::RSDS_WS, nodes, reps, true);
        print_speedups(
            &format!(
                "Fig 6: rsds/ws vs dask/ws under ZERO WORKER, {nodes} node(s) = {} workers",
                nodes * 24
            ),
            &series,
        );
        let (lo, hi) = (1.1, 6.0);
        let in_band = series.rows.iter().filter(|(_, s)| (lo..=hi).contains(s)).count();
        println!(
            "  paper band: {lo}–{hi}×; {}/{} benchmarks inside",
            in_band,
            series.rows.len()
        );
    }
}
