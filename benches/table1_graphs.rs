//! Table I — task graph properties of the benchmark suite: print the
//! generated statistics next to the paper's published row and flag
//! deviations beyond tolerance. Also times graph generation (the client-
//! side cost of building each benchmark).

use rsds::bench::{bench, row, BenchConfig};
use rsds::graphgen::paper_suite;
use rsds::taskgraph::GraphStats;

fn main() {
    println!("TABLE I — task graph properties (generated vs paper)\n");
    println!(
        "{:<28} {:>8} {:>8} {:>10} {:>10} {:>4}   paper [#T #I S AD LP]",
        "benchmark", "#T", "#I", "S[KiB]", "AD[ms]", "LP"
    );
    let mut mismatches = Vec::new();
    for entry in paper_suite() {
        let stats = GraphStats::of(&entry.graph());
        println!(
            "{}   [{} {} {} {} {}]",
            stats.row(entry.name),
            entry.paper.n_tasks,
            entry.paper.n_deps,
            entry.paper.avg_output_kib,
            entry.paper.avg_duration_ms,
            entry.paper.longest_path
        );
        mismatches.extend(entry.verify());
    }
    if mismatches.is_empty() {
        println!("\nall entries within tolerance of the paper's Table I");
    } else {
        println!("\nDEVIATIONS:");
        for m in &mismatches {
            println!("  {m}");
        }
    }

    println!("\ngraph generation cost:");
    let cfg = BenchConfig::from_env();
    for name in ["merge-100K", "bag-large", "numpy-fine", "groupby-xl"] {
        let entry = paper_suite().into_iter().find(|e| e.name == name).unwrap();
        let r = bench(name, cfg, || entry.graph());
        println!("  {}", row(&r));
    }
}
