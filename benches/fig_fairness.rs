//! Fairness (PR 4 extension) — P99 small-run latency under a large-run
//! background load, per dispatch policy.
//!
//! PR 1's multi-graph server drained each run's outbound messages in
//! arrival order, so one 100K-task submission starved a 10-task one. The
//! reactor (and the simulator, which mirrors it) now parks messages on
//! per-run outboxes and services them in bounded rounds under a pluggable
//! fairness policy. This bench submits one large merge graph plus a batch
//! of small ones to the simulator and reports the small runs' latency
//! (P99/P50 of per-run makespan, which includes the dispatch wait) under
//! `arrival` (the pre-fairness baseline), `rr` (round-robin, the default)
//! and `weighted` (shortest-remaining-first). Machine-readable results go
//! to `BENCH_pr4.json`; the run *asserts* that both fair policies strictly
//! beat the baseline.

use rsds::graphgen::merge;
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate_concurrent, SimConfig};
use rsds::taskgraph::TaskGraph;
use rsds::util::stats::percentile_sorted;

struct Row {
    policy: &'static str,
    profile: &'static str,
    n_small: usize,
    small_p99_us: f64,
    small_p50_us: f64,
    large_makespan_us: f64,
}

fn measure(
    policy: &'static str,
    profile_name: &'static str,
    profile: RuntimeProfile,
    scheduler: &str,
    large: usize,
    n_small: usize,
) -> Row {
    let graphs: Vec<TaskGraph> =
        std::iter::once(merge(large)).chain((0..n_small).map(|_| merge(50))).collect();
    let cfg = SimConfig {
        n_workers: 24,
        profile,
        scheduler: scheduler.into(),
        fairness: policy.into(),
        ..SimConfig::default()
    };
    let r = simulate_concurrent(&graphs, &cfg);
    assert!(!r.timed_out, "{policy}/{profile_name}: timed out");
    assert_eq!(r.in_flight_steals_at_end, 0, "{policy}/{profile_name}: leaked steals");
    let mut smalls: Vec<f64> = r.runs[1..].iter().map(|x| x.makespan_us).collect();
    smalls.sort_by(|a, b| a.partial_cmp(b).expect("no NaN makespans"));
    Row {
        policy,
        profile: profile_name,
        n_small,
        small_p99_us: percentile_sorted(&smalls, 0.99),
        small_p50_us: percentile_sorted(&smalls, 0.50),
        large_makespan_us: r.runs[0].makespan_us,
    }
}

fn write_bench_json(rows: &[Row], quick: bool) {
    let baseline = |profile: &str| {
        rows.iter()
            .find(|r| r.policy == "arrival" && r.profile == profile)
            .expect("arrival baseline measured")
            .small_p99_us
    };
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 4,\n");
    json.push_str("  \"bench\": \"fig_fairness\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"policy\": \"{}\", \"profile\": \"{}\", \"n_small\": {}, \
             \"small_p99_us\": {:.2}, \"small_p50_us\": {:.2}, \
             \"large_makespan_us\": {:.2}, \"p99_speedup_vs_arrival\": {:.3}}}{}\n",
            r.policy,
            r.profile,
            r.n_small,
            r.small_p99_us,
            r.small_p50_us,
            r.large_makespan_us,
            baseline(r.profile) / r.small_p99_us,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr4.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr4.json"),
        Err(e) => eprintln!("could not write BENCH_pr4.json: {e}"),
    }
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let (large, n_small) = if quick { (3_000, 8) } else { (20_000, 16) };
    let profiles: Vec<(&'static str, RuntimeProfile, &'static str)> = if quick {
        vec![("rsds", RuntimeProfile::rust(), "ws")]
    } else {
        vec![
            ("rsds", RuntimeProfile::rust(), "ws"),
            ("dask", RuntimeProfile::python(), "dask-ws"),
        ]
    };

    println!(
        "== fig_fairness: small-run latency under a merge-{large} background load \
         ({n_small} × merge-50, 24 workers) =="
    );
    println!(
        "{:<10} {:<8} {:>16} {:>16} {:>16} {:>10}",
        "policy", "profile", "small P99 µs", "small P50 µs", "large mksp µs", "vs arrival"
    );
    let mut rows = Vec::new();
    for &(pname, ref profile, sched) in &profiles {
        for policy in ["arrival", "rr", "weighted"] {
            let row = measure(policy, pname, profile.clone(), sched, large, n_small);
            rows.push(row);
        }
        let base = rows
            .iter()
            .find(|r| r.policy == "arrival" && r.profile == pname)
            .expect("baseline first")
            .small_p99_us;
        for r in rows.iter().filter(|r| r.profile == pname) {
            println!(
                "{:<10} {:<8} {:>16.1} {:>16.1} {:>16.1} {:>9.1}x",
                r.policy,
                r.profile,
                r.small_p99_us,
                r.small_p50_us,
                r.large_makespan_us,
                base / r.small_p99_us
            );
        }
    }

    // Acceptance: fair policies strictly beat arrival order on small-run
    // P99 for every profile.
    for &(pname, _, _) in &profiles {
        let get = |policy: &str| {
            rows.iter()
                .find(|r| r.policy == policy && r.profile == pname)
                .expect("all policies measured")
                .small_p99_us
        };
        let (arrival, rr, weighted) = (get("arrival"), get("rr"), get("weighted"));
        assert!(
            rr < arrival,
            "{pname}: round-robin P99 {rr:.1} must beat arrival {arrival:.1}"
        );
        assert!(
            weighted < arrival,
            "{pname}: weighted P99 {weighted:.1} must beat arrival {arrival:.1}"
        );
    }
    write_bench_json(&rows, quick);
    println!(
        "\nsmall-run latency = per-run makespan (submission→last finish, includes \
         dispatch wait); arrival = pre-fairness drain order"
    );
}
