//! Worker↔worker data-plane throughput (PR 10) — pooled persistent peer
//! links + batched pipelined gather vs the pre-PR-10 baseline
//! (one TCP connect per fetched object, fetched sequentially).
//!
//! The workload is a wide fan-in: waves of cheap producers feeding one
//! `MergeInputs` sink each, on two single-node workers under the
//! work-stealing scheduler, so roughly half of every sink's inputs live
//! on the peer worker. Per-object transfer setup is what the pooled data
//! plane removes (one link + one `fetch-data-many` round trip per peer
//! per gather instead of connect+request+reply per object), so tasks/s
//! on this shape is the acceptance metric: pooled must be ≥ 2× baseline
//! (full run; the quick CI smoke asserts ≥ 1.3× to absorb loopback
//! noise on shared runners).
//!
//! Results are printed and emitted machine-readably to `BENCH_pr10.json`.
//!
//! Env knobs: `RSDS_BENCH_QUICK=1` shortens runs (CI smoke);
//! `RSDS_BENCH_SECTION=dataplane` runs the (only) section explicitly.

use std::time::Instant;

use rsds::client::Client;
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::taskgraph::{GraphBuilder, Payload, TaskGraph};
use rsds::worker::dataplane::DataPlaneConfig;
use rsds::worker::{run_worker, WorkerConfig};

struct Row {
    mode: &'static str,
    waves: u32,
    width: u32,
    object_bytes: u64,
    n_tasks: u64,
    wall_us: f64,
}

impl Row {
    fn tasks_per_s(&self) -> f64 {
        self.n_tasks as f64 / (self.wall_us / 1e6)
    }
}

/// `waves` independent fan-ins: `width` cheap producers each emitting
/// `bytes`, merged by one sink. Independent waves overlap across the two
/// workers, so the run measures sustained gather throughput rather than
/// a single cold fetch.
fn fanin_graph(waves: u32, width: u32, bytes: u64) -> TaskGraph {
    let mut b = GraphBuilder::new();
    for w in 0..waves {
        let ids: Vec<_> = (0..width)
            .map(|i| b.add(format!("p{w}-{i}"), vec![], 100, bytes, Payload::NoOp))
            .collect();
        b.add(format!("sink{w}"), ids, 100, 64, Payload::MergeInputs);
    }
    b.build("dataplane-fanin").expect("valid graph")
}

/// One real-TCP run: server + two workers on distinct nodes, the fan-in
/// graph, wall-clock from submit to result. `pooled = false` restores the
/// connect-per-fetch, one-object-per-request baseline inside the same
/// binary, so the two rows differ only in the data plane under test.
fn measure(mode: &'static str, pooled: bool, waves: u32, width: u32, bytes: u64) -> Row {
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 2020,
        profile: RuntimeProfile::rust(),
        emulate: false,
        ..ServerConfig::default()
    })
    .expect("server start");
    let addr = srv.addr.to_string();
    let dp = DataPlaneConfig { pooled, ..DataPlaneConfig::default() };
    let workers: Vec<_> = (0..2u32)
        .map(|i| {
            run_worker(WorkerConfig {
                server_addr: addr.clone(),
                name: format!("dp-{mode}-w{i}"),
                ncores: 2,
                node: i,
                memory_limit: None,
                data_plane: dp.clone(),
            })
            .expect("worker start")
        })
        .collect();
    let graph = fanin_graph(waves, width, bytes);
    let mut client = Client::connect(&addr, "fig-dataplane").expect("client connect");
    let t0 = Instant::now();
    let res = client.run_graph(&graph).expect("fan-in run completes");
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    assert_eq!(res.n_tasks, graph.len() as u64, "{mode}: all tasks must complete");
    drop(client);
    for w in workers {
        w.shutdown();
    }
    srv.shutdown();
    Row { mode, waves, width, object_bytes: bytes, n_tasks: res.n_tasks, wall_us }
}

fn write_bench_json(rows: &[Row], speedup: f64, quick: bool) {
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 10,\n");
    json.push_str("  \"bench\": \"fig_dataplane\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"pooled_speedup\": {speedup:.3},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"waves\": {}, \"width\": {}, \"object_bytes\": {}, \
             \"n_tasks\": {}, \"wall_us\": {:.0}, \"tasks_per_s\": {:.1}}}{}\n",
            r.mode,
            r.waves,
            r.width,
            r.object_bytes,
            r.n_tasks,
            r.wall_us,
            r.tasks_per_s(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr10.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr10.json (pooled speedup {speedup:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_pr10.json: {e}"),
    }
}

fn dataplane_section(quick: bool) {
    let (waves, width, bytes): (u32, u32, u64) =
        if quick { (6, 32, 4 * 1024) } else { (16, 48, 8 * 1024) };
    println!(
        "== fig_dataplane: {waves} waves of {width}-wide fan-in, {bytes} B objects, \
         2 workers / 2 nodes =="
    );
    println!("{:<10} {:>8} {:>12} {:>12}", "mode", "tasks", "wall ms", "tasks/s");
    let mut rows = Vec::new();
    for (mode, pooled) in [("baseline", false), ("pooled", true)] {
        let row = measure(mode, pooled, waves, width, bytes);
        println!(
            "{:<10} {:>8} {:>12.1} {:>12.1}",
            row.mode,
            row.n_tasks,
            row.wall_us / 1e3,
            row.tasks_per_s()
        );
        rows.push(row);
    }
    let speedup = rows[1].tasks_per_s() / rows[0].tasks_per_s();
    let floor = if quick { 1.3 } else { 2.0 };
    println!(
        "\npooled/baseline tasks/s: {:.2}x (gate: >= {floor}x{})",
        speedup,
        if quick { ", quick" } else { "" }
    );
    assert!(
        speedup >= floor,
        "pooled data plane must be >= {floor}x baseline tasks/s on wide fan-in, got {speedup:.2}x"
    );
    write_bench_json(&rows, speedup, quick);
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let section = std::env::var("RSDS_BENCH_SECTION").unwrap_or_default();
    if section.is_empty() || section == "dataplane" {
        dataplane_section(quick);
    }
}
