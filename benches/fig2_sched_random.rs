//! Fig 2 — speedup of the random scheduler inside the Dask server, with
//! Dask/work-stealing as the baseline, on 1-node (24w) and 7-node (168w)
//! clusters over the full benchmark suite.
//!
//! Paper shape: random lands mostly between 0.5× and 1.4×, geomean 0.88×
//! at 24 workers and 0.95× at 168 — closer to ws on the larger cluster.

use rsds::bench::paper::{print_speedups, reps_from_env, speedups, Combo};
use rsds::graphgen::paper_suite;

fn main() {
    let suite = paper_suite();
    let reps = reps_from_env(3);
    for nodes in [1usize, 7] {
        let series = speedups(&suite, Combo::DASK_WS, Combo::DASK_RANDOM, nodes, reps, false);
        print_speedups(
            &format!("Fig 2: dask/random vs dask/ws, {nodes} node(s) = {} workers", nodes * 24),
            &series,
        );
        let paper = if nodes == 1 { 0.88 } else { 0.95 };
        println!("  paper geomean at this size: {paper}×");
    }
}
