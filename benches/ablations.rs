//! Ablations of the design choices DESIGN.md calls out, plus the paper's
//! §VII future-work experiment ("quantify the effect of improving worker
//! performance on the overall workflow runtime").
//!
//! A — ws without balancing: locality placement alone vs locality+steal.
//! B — worker-overhead sweep: how much a faster *worker* (the paper's
//!     other future-work axis) buys under each server.
//! C — scheduler-thread isolation (GIL ablation): run the python profile
//!     with the scheduler on its own thread.

use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate, SimConfig};
use rsds::util::stats::fmt_us;

fn main() {
    // --- A: balancing on/off (rsds server) ---
    println!("== Ablation A: RSDS ws with vs without steal balancing ==");
    println!("{:<24} {:>8} {:>14} {:>14} {:>8}", "graph", "workers", "ws", "ws-nobalance", "gain");
    for (spec, workers) in [
        ("merge-50000", 168usize),
        ("xarray-25", 24),
        ("groupby-2880-16s-16h", 168),
        ("tree-15", 24),
    ] {
        let graph = graphgen::parse(spec).unwrap();
        let with = simulate(
            &graph,
            &SimConfig { n_workers: workers, scheduler: "ws".into(), ..SimConfig::default() },
        );
        let without = simulate(
            &graph,
            &SimConfig {
                n_workers: workers,
                scheduler: "ws-nobalance".into(),
                ..SimConfig::default()
            },
        );
        println!(
            "{:<24} {:>8} {:>14} {:>14} {:>7.2}×",
            spec,
            workers,
            fmt_us(with.makespan_us),
            fmt_us(without.makespan_us),
            without.makespan_us / with.makespan_us
        );
    }
    println!("(balancing matters where locality piles consumers on data holders)");

    // --- B: worker-overhead sweep (paper §VII future work) ---
    println!("\n== Ablation B: effect of improving the worker (per-task overhead sweep) ==");
    // 24 workers: the worker-bound regime, where a faster worker can pay
    // off — if the server lets it.
    let graph = graphgen::merge(50_000);
    println!("{:<16} {:>14} {:>14} {:>9}", "worker ovh", "rsds/ws", "dask/ws", "ratio");
    for ovh in [5_000.0f64, 2_000.0, 500.0, 100.0, 0.0] {
        let mut rust = RuntimeProfile::rust();
        rust.worker_task_overhead_us = ovh;
        let mut py = RuntimeProfile::python();
        py.worker_task_overhead_us = ovh;
        let r = simulate(
            &graph,
            &SimConfig { n_workers: 24, profile: rust, scheduler: "ws".into(), ..SimConfig::default() },
        );
        let d = simulate(
            &graph,
            &SimConfig { n_workers: 24, profile: py, scheduler: "dask-ws".into(), ..SimConfig::default() },
        );
        println!(
            "{:<16} {:>14} {:>14} {:>8.2}×",
            format!("{} µs", ovh),
            fmt_us(r.makespan_us),
            fmt_us(d.makespan_us),
            d.makespan_us / r.makespan_us
        );
    }
    println!("(paper §VI-D prediction: RSDS benefits more from a faster worker — the");
    println!(" server it exposes is not the bottleneck, Dask's is)");

    // --- C: GIL ablation ---
    println!("\n== Ablation C: Dask profile with/without the GIL (scheduler thread) ==");
    let graph = graphgen::merge(50_000);
    let mut nogil = RuntimeProfile::python();
    nogil.gil = false;
    for (label, profile) in [("dask (GIL)", RuntimeProfile::python()), ("dask (no GIL)", nogil)] {
        let r = simulate(
            &graph,
            &SimConfig {
                n_workers: 168,
                profile,
                scheduler: "dask-ws".into(),
                ..SimConfig::default()
            },
        );
        println!("  {:<16} {:>14}", label, fmt_us(r.makespan_us));
    }
    println!("(isolating the scheduler thread — the paper's §IV-A design — helps even");
    println!(" at Python-level per-event costs)");
}
