//! Fig 5 — strong scaling of Dask and RSDS (both with their work-stealing
//! schedulers) on merge-100K, the groupby table workload, and merge_slow
//! at 0.01 / 0.1 / 1 s task durations, over 1–63 nodes (24–1512 workers).
//!
//! Paper shapes: RSDS scales merge-100K to ~15 nodes then flattens; Dask
//! is ~2× slower at 1 node and degrades with every added node (4× at 63);
//! Dask stops scaling groupby at 7 nodes, RSDS at ~23; with 1 s tasks both
//! scale to 63 nodes with RSDS 1.03×→1.6× ahead.
//!
//! Writes the series to results/fig5_scaling.csv.

use rsds::bench::paper::reps_from_env;
use rsds::graphgen;
use rsds::metrics::{write_csv, Measurement};
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate, SimConfig};
use rsds::util::stats::fmt_us;

fn main() {
    let reps = reps_from_env(2); // the paper used 2 reps for scaling
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let nodes: &[usize] = if quick { &[1, 7, 31] } else { &[1, 3, 7, 15, 23, 31, 47, 63] };

    let graphs = vec![
        graphgen::merge(100_000),
        graphgen::parse("groupby-2880-16s-16h").unwrap(),
        graphgen::merge_slow(20_000, 10_000),
        graphgen::merge_slow(20_000, 100_000),
        graphgen::merge_slow(20_000, 1_000_000),
    ];

    let mut rows: Vec<Measurement> = Vec::new();
    for graph in &graphs {
        println!("\n== Fig 5: {} ==", graph.name);
        println!("{:>6} {:>8} {:>14} {:>14} {:>9}", "nodes", "workers", "rsds/ws", "dask/ws", "ratio");
        for &n in nodes {
            let mut means = [0.0f64; 2];
            for (i, (profile, sched, server)) in [
                (RuntimeProfile::rust(), "ws", "rsds"),
                (RuntimeProfile::python(), "dask-ws", "dask"),
            ]
            .into_iter()
            .enumerate()
            {
                let mut total = 0.0;
                for rep in 0..reps {
                    let cfg = SimConfig {
                        seed: 2020 + rep as u64,
                        ..SimConfig::nodes(n, profile.clone(), sched)
                    };
                    total += simulate(graph, &cfg).makespan_us;
                }
                let mean = total / reps as f64;
                means[i] = mean;
                rows.push(Measurement {
                    benchmark: graph.name.clone(),
                    server: server.into(),
                    scheduler: "ws".into(),
                    n_workers: n * 24,
                    n_nodes: n,
                    makespan_us: mean,
                    reps,
                    aot_us: mean / graph.len() as f64,
                });
            }
            println!(
                "{:>6} {:>8} {:>14} {:>14} {:>8.2}×",
                n,
                n * 24,
                fmt_us(means[0]),
                fmt_us(means[1]),
                means[1] / means[0]
            );
        }
    }
    if let Err(e) = write_csv("results/fig5_scaling.csv", &rows) {
        eprintln!("csv write failed: {e}");
    } else {
        println!("\nwrote results/fig5_scaling.csv ({} rows)", rows.len());
    }
}
