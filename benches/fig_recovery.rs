//! Recovery overhead (PR 3 extension) — AOT cost of killing 1-of-N workers
//! mid-run vs a clean run.
//!
//! The paper benchmarks a healthy cluster; this measures what lineage
//! recovery costs when a worker dies at 30 % of the clean makespan: lost
//! queue entries are re-placed, outputs whose only replica died are
//! recomputed transitively, and the run completes on the survivors. Clean
//! AOT, killed AOT, the overhead ratio and the number of re-executed tasks
//! are reported per (scheduler, graph, cluster) combination and emitted
//! machine-readably to `BENCH_pr3.json`.

use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate, SimConfig, WorkerKill};
use rsds::taskgraph::TaskGraph;

struct Row {
    scheduler: &'static str,
    graph: String,
    n_workers: usize,
    clean_aot_us: f64,
    killed_aot_us: f64,
    reexecuted: u64,
    recoveries: u64,
}

impl Row {
    fn overhead(&self) -> f64 {
        self.killed_aot_us / self.clean_aot_us
    }
}

fn measure(graph: &TaskGraph, sched: &'static str, n_workers: usize) -> Row {
    let base = SimConfig {
        n_workers,
        profile: RuntimeProfile::rust(),
        scheduler: sched.into(),
        ..SimConfig::default()
    };
    let clean = simulate(graph, &base);
    assert!(!clean.timed_out, "{sched}/{}: clean run timed out", graph.name);
    let killed = simulate(
        graph,
        &SimConfig {
            kill: Some(WorkerKill { worker: 0, at_us: clean.makespan_us * 0.3 }),
            ..base
        },
    );
    assert!(!killed.timed_out, "{sched}/{}: killed run timed out", graph.name);
    assert_eq!(killed.n_tasks, graph.len() as u64);
    Row {
        scheduler: sched,
        graph: graph.name.clone(),
        n_workers,
        clean_aot_us: clean.aot_us,
        killed_aot_us: killed.aot_us,
        reexecuted: killed.tasks_executed.saturating_sub(killed.n_tasks),
        recoveries: killed.recoveries,
    }
}

fn write_bench_json(rows: &[Row], quick: bool) {
    let geomean =
        (rows.iter().map(|r| r.overhead().ln()).sum::<f64>() / rows.len() as f64).exp();
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 3,\n");
    json.push_str("  \"bench\": \"fig_recovery\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"geomean_kill_overhead\": {geomean:.3},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"scheduler\": \"{}\", \"graph\": \"{}\", \"n_workers\": {}, \
             \"clean_aot_us\": {:.2}, \"killed_aot_us\": {:.2}, \"overhead\": {:.3}, \
             \"reexecuted_tasks\": {}, \"recoveries\": {}}}{}\n",
            r.scheduler,
            r.graph,
            r.n_workers,
            r.clean_aot_us,
            r.killed_aot_us,
            r.overhead(),
            r.reexecuted,
            r.recoveries,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr3.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr3.json (geomean kill overhead {geomean:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_pr3.json: {e}"),
    }
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let graphs: Vec<TaskGraph> = if quick {
        vec![graphgen::merge_slow(200, 2_000), graphgen::tree(7)]
    } else {
        vec![
            graphgen::merge_slow(2_000, 2_000),
            graphgen::tree(10),
            graphgen::xarray(25),
        ]
    };
    let clusters: &[usize] = if quick { &[8] } else { &[8, 24] };

    println!("== fig_recovery: AOT with 1-of-N workers killed at 30% of makespan ==");
    println!(
        "{:<10} {:<18} {:>8} {:>14} {:>14} {:>9} {:>8}",
        "sched", "graph", "workers", "clean µs/task", "killed µs/task", "overhead", "re-exec"
    );
    let mut rows = Vec::new();
    for graph in &graphs {
        for sched in ["random", "ws", "dask-ws"] {
            for &n in clusters {
                let row = measure(graph, sched, n);
                println!(
                    "{:<10} {:<18} {:>8} {:>14.2} {:>14.2} {:>8.2}x {:>8}",
                    row.scheduler,
                    row.graph,
                    row.n_workers,
                    row.clean_aot_us,
                    row.killed_aot_us,
                    row.overhead(),
                    row.reexecuted
                );
                rows.push(row);
            }
        }
    }
    write_bench_json(&rows, quick);
    println!(
        "\nAOT = makespan / #tasks; overhead = killed AOT / clean AOT; \
         re-exec = task executions beyond one per task (lineage recompute)"
    );
}
