//! Recovery overhead (PR 3 extension) — AOT cost of killing 1-of-N workers
//! mid-run vs a clean run.
//!
//! The paper benchmarks a healthy cluster; this measures what lineage
//! recovery costs when a worker dies at 30 % of the clean makespan: lost
//! queue entries are re-placed, outputs whose only replica died are
//! recomputed transitively, and the run completes on the survivors. Clean
//! AOT, killed AOT, the overhead ratio and the number of re-executed tasks
//! are reported per (scheduler, graph, cluster) combination and emitted
//! machine-readably to `BENCH_pr3.json`.
//!
//! The replication section (PR 8) re-runs the kill experiment at k = 2:
//! proactive replication should turn most of the lost-output recomputes
//! into trivial `who_has` purges (≥ 50 % fewer re-executed tasks on the
//! same graph and seed — the PR 8 acceptance gate), and a real TCP run
//! under `--memory-limit` must spill, restore, and still complete a graph
//! whose live outputs exceed the budget. Emitted to `BENCH_pr8.json`.
//!
//! Env knobs: `RSDS_BENCH_QUICK=1` shortens runs (CI smoke);
//! `RSDS_BENCH_SECTION=recovery|replication` runs one section only.

use rsds::client::Client;
use rsds::graphgen;
use rsds::overhead::RuntimeProfile;
use rsds::server::{serve, ServerConfig};
use rsds::sim::{simulate, SimConfig, WorkerKill};
use rsds::taskgraph::{GraphBuilder, Payload, TaskGraph};
use rsds::worker::{run_worker, WorkerConfig};

struct Row {
    scheduler: &'static str,
    graph: String,
    n_workers: usize,
    clean_aot_us: f64,
    killed_aot_us: f64,
    reexecuted: u64,
    recoveries: u64,
}

impl Row {
    fn overhead(&self) -> f64 {
        self.killed_aot_us / self.clean_aot_us
    }
}

fn measure(graph: &TaskGraph, sched: &'static str, n_workers: usize) -> Row {
    let base = SimConfig {
        n_workers,
        profile: RuntimeProfile::rust(),
        scheduler: sched.into(),
        ..SimConfig::default()
    };
    let clean = simulate(graph, &base);
    assert!(!clean.timed_out, "{sched}/{}: clean run timed out", graph.name);
    let killed = simulate(
        graph,
        &SimConfig {
            kill: Some(WorkerKill { worker: 0, at_us: clean.makespan_us * 0.3 }),
            ..base
        },
    );
    assert!(!killed.timed_out, "{sched}/{}: killed run timed out", graph.name);
    assert_eq!(killed.n_tasks, graph.len() as u64);
    Row {
        scheduler: sched,
        graph: graph.name.clone(),
        n_workers,
        clean_aot_us: clean.aot_us,
        killed_aot_us: killed.aot_us,
        reexecuted: killed.tasks_executed.saturating_sub(killed.n_tasks),
        recoveries: killed.recoveries,
    }
}

fn write_bench_json(rows: &[Row], quick: bool) {
    let geomean =
        (rows.iter().map(|r| r.overhead().ln()).sum::<f64>() / rows.len() as f64).exp();
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 3,\n");
    json.push_str("  \"bench\": \"fig_recovery\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"geomean_kill_overhead\": {geomean:.3},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"scheduler\": \"{}\", \"graph\": \"{}\", \"n_workers\": {}, \
             \"clean_aot_us\": {:.2}, \"killed_aot_us\": {:.2}, \"overhead\": {:.3}, \
             \"reexecuted_tasks\": {}, \"recoveries\": {}}}{}\n",
            r.scheduler,
            r.graph,
            r.n_workers,
            r.clean_aot_us,
            r.killed_aot_us,
            r.overhead(),
            r.reexecuted,
            r.recoveries,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr3.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr3.json (geomean kill overhead {geomean:.2}x)"),
        Err(e) => eprintln!("could not write BENCH_pr3.json: {e}"),
    }
}

// ---------------------------------------------------------------------------
// PR 8: k-replication vs recompute, and spill-to-disk completion.
// ---------------------------------------------------------------------------

struct ReplRow {
    graph: String,
    replication: usize,
    killed_aot_us: f64,
    reexecuted: u64,
    recoveries: u64,
}

/// One killed run at replication factor `k` (fan-out threshold 1 so every
/// consumed output is a replication candidate — the contrast experiment
/// wants the policy on, not a policy study).
fn measure_replicated(graph: &TaskGraph, n_workers: usize, k: usize) -> ReplRow {
    let base = SimConfig {
        n_workers,
        profile: RuntimeProfile::rust(),
        scheduler: "ws".into(),
        replication: k,
        replication_fanout: 1,
        ..SimConfig::default()
    };
    let clean = simulate(graph, &base);
    assert!(!clean.timed_out, "k={k}/{}: clean run timed out", graph.name);
    let killed = simulate(
        graph,
        &SimConfig {
            kill: Some(WorkerKill { worker: 0, at_us: clean.makespan_us * 0.3 }),
            ..base
        },
    );
    assert!(!killed.timed_out, "k={k}/{}: killed run timed out", graph.name);
    assert_eq!(killed.n_tasks, graph.len() as u64);
    ReplRow {
        graph: graph.name.clone(),
        replication: k,
        killed_aot_us: killed.aot_us,
        reexecuted: killed.tasks_executed.saturating_sub(killed.n_tasks),
        recoveries: killed.recoveries,
    }
}

/// A graph whose live outputs exceed the spill run's memory budget: every
/// chunk stays live (its sole consumer is the final sink), so the worker
/// must spill mid-run and restore at the gather.
fn spill_graph(chunks: u32, chunk_bytes: u64) -> TaskGraph {
    let mut b = GraphBuilder::new();
    let ids: Vec<_> = (0..chunks)
        .map(|i| b.add(&format!("chunk-{i}"), vec![], 200, chunk_bytes, Payload::NoOp))
        .collect();
    b.add("spill-sink", ids, 500, 64, Payload::MergeInputs);
    b.build("spill-pressure").expect("valid graph")
}

struct SpillOutcome {
    memory_limit: u64,
    live_bytes: u64,
    spills: u64,
    restores: u64,
}

/// Real TCP run: one worker under `--memory-limit`, a graph holding 6×
/// the budget live. Completion plus non-zero spill/restore counters is
/// the PR 8 spill acceptance gate.
fn spill_run(quick: bool) -> SpillOutcome {
    let limit: u64 = 64 * 1024;
    let chunks: u32 = if quick { 24 } else { 48 };
    let chunk_bytes: u64 = 16 * 1024;
    let srv = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        scheduler: "ws".into(),
        seed: 2020,
        profile: RuntimeProfile::rust(),
        emulate: false,
        ..ServerConfig::default()
    })
    .expect("server start");
    let addr = srv.addr.to_string();
    let w = run_worker(WorkerConfig {
        server_addr: addr.clone(),
        name: "spill-w0".into(),
        ncores: 1,
        node: 0,
        memory_limit: Some(limit),
        data_plane: Default::default(),
    })
    .expect("worker start");
    let graph = spill_graph(chunks, chunk_bytes);
    let mut client = Client::connect(&addr, "fig-recovery").expect("client connect");
    let res = client.run_graph(&graph).expect("spill run completes");
    assert_eq!(res.n_tasks, chunks as u64 + 1, "graph exceeding the budget must complete");
    let (spills, restores) = w.spill_stats();
    w.shutdown();
    srv.shutdown();
    assert!(spills > 0, "live set 6x the budget never spilled");
    assert!(restores > 0, "sink gather never restored a spilled chunk");
    SpillOutcome { memory_limit: limit, live_bytes: chunks as u64 * chunk_bytes, spills, restores }
}

fn write_pr8_json(rows: &[ReplRow], spill: &SpillOutcome, quick: bool) {
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 8,\n");
    json.push_str("  \"bench\": \"fig_recovery_replication\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"graph\": \"{}\", \"replication\": {}, \"killed_aot_us\": {:.2}, \
             \"reexecuted_tasks\": {}, \"recoveries\": {}}}{}\n",
            r.graph,
            r.replication,
            r.killed_aot_us,
            r.reexecuted,
            r.recoveries,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"spill\": {{\"memory_limit\": {}, \"live_bytes\": {}, \"spills\": {}, \
         \"restores\": {}, \"completed\": true}}\n",
        spill.memory_limit, spill.live_bytes, spill.spills, spill.restores
    ));
    json.push_str("}\n");
    match std::fs::write("BENCH_pr8.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr8.json"),
        Err(e) => eprintln!("could not write BENCH_pr8.json: {e}"),
    }
}

fn replication_section(quick: bool) {
    println!("\n== fig_recovery: replication (k=1 vs k=2, worker 0 killed at 30%) ==");
    let graphs: Vec<TaskGraph> = if quick {
        vec![graphgen::merge_slow(200, 2_000), graphgen::tree(7)]
    } else {
        vec![graphgen::merge_slow(2_000, 2_000), graphgen::tree(10)]
    };
    println!(
        "{:<18} {:>3} {:>14} {:>9} {:>10}",
        "graph", "k", "killed µs/task", "re-exec", "recoveries"
    );
    let mut rows = Vec::new();
    for graph in &graphs {
        for k in [1usize, 2] {
            let row = measure_replicated(graph, 8, k);
            println!(
                "{:<18} {:>3} {:>14.2} {:>9} {:>10}",
                row.graph, row.replication, row.killed_aot_us, row.reexecuted, row.recoveries
            );
            rows.push(row);
        }
    }
    // The acceptance gate, on the first (merge) graph: same graph, same
    // seed, same kill point — k=2 must recompute at most half of what
    // k=1 recomputes.
    let k1 = rows.iter().find(|r| r.replication == 1).expect("k=1 row");
    let k2 = rows.iter().find(|r| r.replication == 2).expect("k=2 row");
    assert!(
        k1.reexecuted > 0,
        "{}: the k=1 kill must lose sole-copy outputs for the contrast to mean anything",
        k1.graph
    );
    assert!(
        k2.reexecuted * 2 <= k1.reexecuted,
        "{}: k=2 must recompute at least 50% fewer tasks (k=1: {}, k=2: {})",
        k1.graph,
        k1.reexecuted,
        k2.reexecuted
    );
    println!(
        "\n{}: re-exec {} (k=1) -> {} (k=2), a {:.0}% reduction",
        k1.graph,
        k1.reexecuted,
        k2.reexecuted,
        100.0 * (1.0 - k2.reexecuted as f64 / k1.reexecuted as f64)
    );

    let spill = spill_run(quick);
    println!(
        "spill: {} live bytes under a {} budget -> {} spills, {} restores, completed",
        spill.live_bytes, spill.memory_limit, spill.spills, spill.restores
    );
    write_pr8_json(&rows, &spill, quick);
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let section = std::env::var("RSDS_BENCH_SECTION").unwrap_or_default();
    if section.is_empty() || section == "recovery" {
        recovery_section(quick);
    }
    if section.is_empty() || section == "replication" {
        replication_section(quick);
    }
}

fn recovery_section(quick: bool) {
    let graphs: Vec<TaskGraph> = if quick {
        vec![graphgen::merge_slow(200, 2_000), graphgen::tree(7)]
    } else {
        vec![
            graphgen::merge_slow(2_000, 2_000),
            graphgen::tree(10),
            graphgen::xarray(25),
        ]
    };
    let clusters: &[usize] = if quick { &[8] } else { &[8, 24] };

    println!("== fig_recovery: AOT with 1-of-N workers killed at 30% of makespan ==");
    println!(
        "{:<10} {:<18} {:>8} {:>14} {:>14} {:>9} {:>8}",
        "sched", "graph", "workers", "clean µs/task", "killed µs/task", "overhead", "re-exec"
    );
    let mut rows = Vec::new();
    for graph in &graphs {
        for sched in ["random", "ws", "dask-ws"] {
            for &n in clusters {
                let row = measure(graph, sched, n);
                println!(
                    "{:<10} {:<18} {:>8} {:>14.2} {:>14.2} {:>8.2}x {:>8}",
                    row.scheduler,
                    row.graph,
                    row.n_workers,
                    row.clean_aot_us,
                    row.killed_aot_us,
                    row.overhead(),
                    row.reexecuted
                );
                rows.push(row);
            }
        }
    }
    write_bench_json(&rows, quick);
    println!(
        "\nAOT = makespan / #tasks; overhead = killed AOT / clean AOT; \
         re-exec = task executions beyond one per task (lineage recompute)"
    );
}
