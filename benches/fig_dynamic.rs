//! Incremental graph submission (PR 9) — AOT of graphs grown via
//! `submit-extend` vs submitted one-shot, over a heterogeneous cluster.
//!
//! The paper submits every graph whole; interactive sessions grow them as
//! results come back. This bench replays each `dynamic_suite()` workload
//! twice per scheduler over a mixed 1/2/4-core cluster: once one-shot, once
//! as a base graph plus extension batches spread across the one-shot
//! makespan (so batches land mid-run, exercising the ready-delta and
//! consumer-delta paths, not just a trailing append). Both runs must
//! execute exactly the same task set — the incremental run completing with
//! `n_tasks` equal to the full graph and no re-executions is asserted, the
//! sim's oversubscription assert covers the multi-core entries — and the
//! per-scheduler AOT plus the incremental/one-shot overhead ratio are
//! reported and emitted machine-readably to `BENCH_pr9.json`.
//!
//! Env knobs: `RSDS_BENCH_QUICK=1` shortens runs (CI smoke).

use rsds::graphgen::{dynamic_suite, DynamicEntry};
use rsds::overhead::RuntimeProfile;
use rsds::sim::{simulate, ExtBatch, SimConfig, SimResult};
use rsds::taskgraph::TaskGraph;

/// The worker heterogeneity axis: cycled core counts per worker.
const CORE_MIX: [u32; 3] = [1, 2, 4];

struct Row {
    scheduler: &'static str,
    graph: String,
    n_workers: usize,
    batches: usize,
    oneshot_aot_us: f64,
    incremental_aot_us: f64,
    msgs_oneshot: u64,
    msgs_incremental: u64,
}

impl Row {
    fn overhead(&self) -> f64 {
        self.incremental_aot_us / self.oneshot_aot_us
    }
}

fn base_cfg(sched: &'static str, n_workers: usize) -> SimConfig {
    SimConfig {
        n_workers,
        profile: RuntimeProfile::rust(),
        scheduler: sched.into(),
        core_mix: CORE_MIX.to_vec(),
        ..SimConfig::default()
    }
}

fn check_clean(r: &SimResult, graph: &TaskGraph, sched: &str, what: &str) {
    assert!(!r.timed_out, "{sched}/{}: {what} run timed out", graph.name);
    assert_eq!(r.n_tasks, graph.len() as u64, "{sched}/{}: {what} lost tasks", graph.name);
    assert_eq!(
        r.tasks_executed, r.n_tasks,
        "{sched}/{}: {what} run re-executed tasks on a clean cluster",
        graph.name
    );
}

/// One (scheduler, entry) measurement: one-shot, then the same graph grown
/// incrementally with batches spread across the one-shot makespan.
fn measure(entry: &DynamicEntry, sched: &'static str, n_workers: usize) -> Row {
    let graph = entry.graph();
    let cfg = base_cfg(sched, n_workers);
    let oneshot = simulate(&graph, &cfg);
    check_clean(&oneshot, &graph, sched, "one-shot");

    let (base, exts) = entry.incremental();
    let n_exts = exts.len();
    let step = oneshot.makespan_us / (n_exts + 1) as f64;
    let extensions: Vec<ExtBatch> = exts
        .into_iter()
        .enumerate()
        .map(|(i, tasks)| ExtBatch {
            run: 0,
            at_us: step * (i + 1) as f64,
            tasks,
            last: i + 1 == n_exts,
        })
        .collect();
    let incremental = simulate(&base, &SimConfig { extensions, ..cfg });
    check_clean(&incremental, &graph, sched, "incremental");

    Row {
        scheduler: sched,
        graph: entry.name.into(),
        n_workers,
        batches: entry.batches,
        oneshot_aot_us: oneshot.aot_us,
        incremental_aot_us: incremental.aot_us,
        msgs_oneshot: oneshot.msgs,
        msgs_incremental: incremental.msgs,
    }
}

fn write_bench_json(rows: &[Row], quick: bool) {
    let mut json = String::from("{\n");
    json.push_str("  \"pr\": 9,\n");
    json.push_str("  \"bench\": \"fig_dynamic\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!(
        "  \"core_mix\": [{}],\n",
        CORE_MIX.map(|c| c.to_string()).join(", ")
    ));
    for sched in ["random", "ws", "dask-ws"] {
        let of: Vec<&Row> = rows.iter().filter(|r| r.scheduler == sched).collect();
        if of.is_empty() {
            continue;
        }
        let geomean =
            (of.iter().map(|r| r.overhead().ln()).sum::<f64>() / of.len() as f64).exp();
        json.push_str(&format!(
            "  \"geomean_incremental_overhead_{}\": {geomean:.3},\n",
            sched.replace('-', "_")
        ));
    }
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"scheduler\": \"{}\", \"graph\": \"{}\", \"n_workers\": {}, \
             \"batches\": {}, \"oneshot_aot_us\": {:.2}, \"incremental_aot_us\": {:.2}, \
             \"overhead\": {:.3}, \"msgs_oneshot\": {}, \"msgs_incremental\": {}}}{}\n",
            r.scheduler,
            r.graph,
            r.n_workers,
            r.batches,
            r.oneshot_aot_us,
            r.incremental_aot_us,
            r.overhead(),
            r.msgs_oneshot,
            r.msgs_incremental,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pr9.json", &json) {
        Ok(()) => println!("\nwrote BENCH_pr9.json"),
        Err(e) => eprintln!("could not write BENCH_pr9.json: {e}"),
    }
}

fn main() {
    let quick = std::env::var_os("RSDS_BENCH_QUICK").is_some();
    let entries: Vec<DynamicEntry> = if quick {
        // One homogeneous + one multi-core entry keeps the smoke run fast
        // while still covering both the extension and the slot-gate paths.
        dynamic_suite().into_iter().take(2).collect()
    } else {
        dynamic_suite()
    };
    let clusters: &[usize] = if quick { &[6] } else { &[6, 24] };

    println!("== fig_dynamic: AOT, one-shot vs incremental submission, 1/2/4-core workers ==");
    println!(
        "{:<10} {:<22} {:>8} {:>8} {:>14} {:>14} {:>9}",
        "sched", "graph", "workers", "batches", "oneshot µs/t", "incr µs/t", "overhead"
    );
    let mut rows = Vec::new();
    for entry in &entries {
        for sched in ["random", "ws", "dask-ws"] {
            for &n in clusters {
                let row = measure(entry, sched, n);
                println!(
                    "{:<10} {:<22} {:>8} {:>8} {:>14.2} {:>14.2} {:>8.2}x",
                    row.scheduler,
                    row.graph,
                    row.n_workers,
                    row.batches,
                    row.oneshot_aot_us,
                    row.incremental_aot_us,
                    row.overhead()
                );
                rows.push(row);
            }
        }
    }
    write_bench_json(&rows, quick);
    println!(
        "\nAOT = makespan / #tasks; overhead = incremental AOT / one-shot AOT \
         (batches arrive spread across the one-shot makespan, so > 1x mostly \
         reflects late work arrival, not scheduler cost)"
    );
}
