"""Build-time Python: JAX/Pallas kernels AOT-lowered to HLO text artifacts.

Never imported at runtime — the Rust workers execute the compiled
artifacts through PJRT (rust/src/runtime/). See DESIGN.md §1/§7.
"""
