"""AOT export: lower every L2 function to HLO **text** artifacts.

HLO text, not ``lowered.compile()`` or serialized protos: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: pathlib.Path) -> dict[str, int]:
    out_dir.mkdir(parents=True, exist_ok=True)
    sizes = {}
    for name, (fn, example_args) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        sizes[name] = len(text)
        print(f"wrote {path} ({len(text)} chars)")
    return sizes


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    args = parser.parse_args()
    export_all(pathlib.Path(args.out))


if __name__ == "__main__":
    main()
