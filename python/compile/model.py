"""L2: the JAX compute graphs executed by worker tasks, calling the L1
Pallas kernels. Lowered once by aot.py; each function below becomes one
HLO-text artifact with a fixed input shape (PJRT AOT is shape-specialized;
the shapes match rust/src/runtime/mod.rs constants).
"""

import jax
import jax.numpy as jnp

from .kernels import feature_hash, partition_reduce

# Fixed artifact shapes — keep in sync with rust/src/runtime/mod.rs.
REDUCE_ROWS, REDUCE_COLS = 256, 128
TRANSPOSE_N = 128
HASH_TOKENS, HASH_BUCKETS = 4096, 1024


def xarray_agg(x):
    """xarray benchmark per-chunk op: anomaly transform + tiled reduction.

    The elementwise part fuses into the Pallas reduction's input in XLA;
    returns [sum, mean] of the anomaly-adjusted chunk.
    """
    anomaly = x - 0.5  # synthetic climatology offset
    return (partition_reduce(anomaly),)


def numpy_step(x):
    """numpy benchmark per-chunk op: (x + x.T) partial sum.

    The transpose+add runs as plain XLA (layout change — no kernel win);
    the reduction reuses the Pallas kernel on the symmetric sum.
    """
    sym = x + x.T
    out = partition_reduce(sym, block_rows=32)
    return (out[:1],)  # [partial_sum]


def vectorize(tokens):
    """vectorizer benchmark per-partition op: hashed feature counts."""
    return (feature_hash(tokens, HASH_BUCKETS),)


#: artifact name -> (function, example args)
ARTIFACTS = {
    "partition_reduce": (
        xarray_agg,
        (jax.ShapeDtypeStruct((REDUCE_ROWS, REDUCE_COLS), jnp.float32),),
    ),
    "numpy_step": (
        numpy_step,
        (jax.ShapeDtypeStruct((TRANSPOSE_N, TRANSPOSE_N), jnp.float32),),
    ),
    "feature_hash": (
        vectorize,
        (jax.ShapeDtypeStruct((HASH_TOKENS,), jnp.int32),),
    ),
}
