"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks the kernels against
(paper-style build-time validation; the Rust side then trusts the
artifacts). Keep them boring: direct jnp expressions, no tiling."""

import jax.numpy as jnp

#: must match kernels.feature_hash.HASH_MULT
HASH_MULT = -1640531527


def partition_reduce_ref(x):
    """[sum, mean] of a 2-D array."""
    s = jnp.sum(x, dtype=jnp.float32)
    return jnp.stack([s, s / x.size])


def feature_hash_ref(tokens, buckets: int = 1024):
    """Bucket-count histogram of multiply-shift-hashed token ids."""
    h = (tokens * jnp.int32(HASH_MULT)) >> 16
    h = jnp.bitwise_and(h, buckets - 1)
    return jnp.zeros(buckets, jnp.float32).at[h].add(1.0)


def numpy_step_ref(x):
    """[partial_sum] of (x + x.T) for one square chunk — the numpy
    benchmark's per-chunk op (dask.array's `(a + a.T).sum()` lowering)."""
    return jnp.sum(x + x.T, dtype=jnp.float32)[None]
