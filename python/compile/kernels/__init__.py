"""L1 Pallas kernels and their pure-jnp reference oracles."""

from .partition_reduce import partition_reduce
from .feature_hash import feature_hash

__all__ = ["partition_reduce", "feature_hash"]
