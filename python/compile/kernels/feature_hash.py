"""L1 Pallas kernel: feature hashing (the vectorizer benchmark's hot loop).

Wordbatch's hashing vectorizer maps each token id to a bucket and counts
bucket hits. TPU adaptation (DESIGN.md §Hardware-Adaptation): Pallas-TPU
has no scatter-add, so the histogram is reformulated as a **one-hot
matmul** — each tile of token ids becomes a (tile, buckets) one-hot f32
matrix whose column-sum accumulates the counts. On a real TPU that matmul
feeds the MXU systolic array; the bucket axis (1024 = 8×128) is padded to
lane width.

Hash: multiply-shift (Dietzfelbinger) on int32, masked to the bucket count
(buckets must be a power of two).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: multiply-shift constant (odd 32-bit): 0x9E3779B9 as signed int32.
#: Plain Python int — a module-level jnp constant would be captured by the
#: Pallas kernel closure, which pallas_call rejects.
HASH_MULT = -1640531527


def _hash_kernel(tokens_ref, counts_ref, *, buckets: int):
    step = pl.program_id(0)
    toks = tokens_ref[...]  # (1, tile) int32
    # Multiply-shift hash, masked to [0, buckets).
    h = (toks * jnp.int32(HASH_MULT)) >> 16
    h = jnp.bitwise_and(h, buckets - 1)
    # One-hot matmul accumulation (MXU-friendly scatter-add substitute).
    onehot = (h[0, :, None] == jnp.arange(buckets, dtype=jnp.int32)[None, :]).astype(
        jnp.float32
    )
    tile_counts = jnp.sum(onehot, axis=0)[None, :]  # (1, buckets)

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = tile_counts

    @pl.when(step != 0)
    def _acc():
        counts_ref[...] = counts_ref[...] + tile_counts


@functools.partial(jax.jit, static_argnames=("buckets", "tile"))
def feature_hash(tokens: jax.Array, buckets: int = 1024, tile: int = 512):
    """Hash int32 token ids into `buckets` counts (f32 vector)."""
    (n,) = tokens.shape
    if n % tile != 0:
        raise ValueError(f"n {n} not divisible by tile {tile}")
    if buckets & (buckets - 1) != 0:
        raise ValueError("buckets must be a power of two")
    counts = pl.pallas_call(
        functools.partial(_hash_kernel, buckets=buckets),
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, buckets), jnp.float32),
        interpret=True,
    )(tokens[None, :])
    return counts[0]
