"""L1 Pallas kernel: tiled sum+mean reduction over a 2-D f32 partition.

The compute hot-spot of the xarray benchmark (grid aggregations, paper §V):
each task reduces one chunk of the air-temperature grid. The kernel tiles
the row axis so each grid step works on an (block_rows, cols) VMEM-resident
tile and accumulates partial sums into a scratch-free running output —
the BlockSpec expresses the HBM→VMEM schedule.

TPU sizing notes (DESIGN.md §Hardware-Adaptation): tiles are (8k, 128)
f32 — lane dimension 128, sublane multiple of 8 — so a (256, 128) partition
at block_rows=64 holds 64×128×4 B = 32 KiB in VMEM, far under the ~16 MiB
budget; the reduction is VPU-bound (no MXU use).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is exactly what the
Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(x_ref, sum_ref):
    """Accumulate the tile's sum into a (1, 1) output."""
    step = pl.program_id(0)
    tile_sum = jnp.sum(x_ref[...])

    @pl.when(step == 0)
    def _init():
        sum_ref[0, 0] = tile_sum

    @pl.when(step != 0)
    def _acc():
        sum_ref[0, 0] = sum_ref[0, 0] + tile_sum


@functools.partial(jax.jit, static_argnames=("block_rows",))
def partition_reduce(x: jax.Array, block_rows: int = 64):
    """Sum and mean of a 2-D f32 partition via a row-tiled Pallas kernel.

    Returns a length-2 f32 vector ``[sum, mean]``.
    """
    rows, cols = x.shape
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not divisible by block_rows {block_rows}")
    grid = (rows // block_rows,)
    total = pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(x)
    s = total[0, 0]
    return jnp.stack([s, s / (rows * cols)])
