"""AOT export pipeline tests: artifacts lower, are deterministic, and the
HLO text is parseable/entry-computation-shaped as the Rust loader expects.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import pytest

from compile.aot import export_all, to_hlo_text
from compile.model import ARTIFACTS


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    export_all(d)
    return d


def test_all_artifacts_written(out_dir):
    for name in ARTIFACTS:
        path = out_dir / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text, f"{name} lacks an entry computation"


def test_artifacts_use_32bit_safe_text(out_dir):
    # The interchange contract: text form (ids reassigned by the parser),
    # never serialized protos (see aot.py docstring).
    for name in ARTIFACTS:
        text = (out_dir / f"{name}.hlo.txt").read_text()
        assert "f32" in text or "s32" in text


def test_export_deterministic(tmp_path):
    a = tmp_path / "a"
    b = tmp_path / "b"
    export_all(a)
    export_all(b)
    for name in ARTIFACTS:
        ta = (a / f"{name}.hlo.txt").read_text()
        tb = (b / f"{name}.hlo.txt").read_text()
        assert ta == tb, f"{name} export not deterministic"


def test_lowered_shapes_match_contract():
    # rust/src/runtime/mod.rs hard-codes these shapes.
    from compile.model import REDUCE_ROWS, REDUCE_COLS, TRANSPOSE_N, HASH_TOKENS

    fn, args = ARTIFACTS["partition_reduce"]
    assert args[0].shape == (REDUCE_ROWS, REDUCE_COLS)
    fn, args = ARTIFACTS["numpy_step"]
    assert args[0].shape == (TRANSPOSE_N, TRANSPOSE_N)
    fn, args = ARTIFACTS["feature_hash"]
    assert args[0].shape == (HASH_TOKENS,)


def test_hlo_text_roundtrip_parses():
    # Sanity: the text we emit can be re-parsed by xla_client itself.
    fn, args = ARTIFACTS["partition_reduce"]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    assert text.count("ENTRY") == 1
