"""Kernel-vs-oracle correctness: the core build-time signal.

Hypothesis sweeps shapes/dtypes/values of both Pallas kernels against the
pure-jnp references in ref.py; the Rust side then trusts the artifacts.
"""

import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import feature_hash, partition_reduce
from compile.kernels.ref import feature_hash_ref, numpy_step_ref, partition_reduce_ref


# ---------- partition_reduce ----------

@pytest.mark.parametrize("rows,cols,block", [(64, 128, 64), (256, 128, 64), (512, 64, 8)])
def test_reduce_matches_ref_basic(rows, cols, block):
    x = jnp.arange(rows * cols, dtype=jnp.float32).reshape(rows, cols) / 1000.0
    got = partition_reduce(x, block_rows=block)
    want = partition_reduce_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    row_blocks=st.integers(1, 6),
    block=st.sampled_from([8, 16, 64]),
    cols=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 100.0),
)
def test_reduce_matches_ref_hypothesis(row_blocks, block, cols, seed, scale):
    rows = row_blocks * block
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (rows, cols), jnp.float32, -scale, scale)
    got = partition_reduce(x, block_rows=block)
    want = partition_reduce_ref(x)
    # Tiled accumulation reorders additions; tolerance covers that.
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-3 * scale)


def test_reduce_special_values():
    x = jnp.zeros((64, 128), jnp.float32)
    np.testing.assert_allclose(partition_reduce(x), [0.0, 0.0])
    x = jnp.full((64, 128), -2.5, jnp.float32)
    got = partition_reduce(x)
    np.testing.assert_allclose(got, [-2.5 * 64 * 128, -2.5], rtol=1e-6)


def test_reduce_rejects_bad_tiling():
    x = jnp.zeros((100, 128), jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        partition_reduce(x, block_rows=64)


# ---------- feature_hash ----------

@pytest.mark.parametrize("n,buckets,tile", [(512, 1024, 512), (4096, 1024, 512), (1024, 256, 256)])
def test_hash_matches_ref_basic(n, buckets, tile):
    tokens = (jnp.arange(n, dtype=jnp.int32) * 7919) % 50_000
    got = feature_hash(tokens, buckets, tile)
    want = feature_hash_ref(tokens, buckets)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 8),
    tile=st.sampled_from([128, 512]),
    buckets=st.sampled_from([128, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hash_matches_ref_hypothesis(tiles, tile, buckets, seed):
    n = tiles * tile
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (n,), 0, 50_000, jnp.int32)
    got = feature_hash(tokens, buckets, tile)
    want = feature_hash_ref(tokens, buckets)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash_counts_conserved():
    tokens = jax.random.randint(jax.random.PRNGKey(0), (4096,), 0, 50_000, jnp.int32)
    counts = feature_hash(tokens, 1024)
    assert float(jnp.sum(counts)) == 4096.0
    assert float(jnp.min(counts)) >= 0.0


def test_hash_rejects_bad_params():
    tokens = jnp.zeros(1000, jnp.int32)
    with pytest.raises(ValueError, match="not divisible"):
        feature_hash(tokens, 1024, 512)
    with pytest.raises(ValueError, match="power of two"):
        feature_hash(jnp.zeros(512, jnp.int32), 1000, 512)


# ---------- L2 model functions ----------

def test_model_numpy_step_matches_ref():
    from compile.model import numpy_step

    x = jax.random.uniform(jax.random.PRNGKey(3), (128, 128), jnp.float32)
    (got,) = numpy_step(x)
    want = numpy_step_ref(x)
    np.testing.assert_allclose(got, want, rtol=2e-4)


def test_model_xarray_agg_is_anomaly_reduce():
    from compile.model import xarray_agg

    x = jax.random.uniform(jax.random.PRNGKey(4), (256, 128), jnp.float32)
    (got,) = xarray_agg(x)
    want = partition_reduce_ref(x - 0.5)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-3)


def test_model_vectorize_shapes():
    from compile.model import vectorize, HASH_TOKENS, HASH_BUCKETS

    tokens = jnp.zeros(HASH_TOKENS, jnp.int32)
    (counts,) = vectorize(tokens)
    assert counts.shape == (HASH_BUCKETS,)
    assert float(jnp.sum(counts)) == HASH_TOKENS
